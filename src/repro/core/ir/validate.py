"""Inter-operator IR validation (shared by the authoring DSL and the
lowering entry point).

Historically a malformed program — an ``EdgeSoftmax`` reading a variable
nobody wrote, an etype-indexed weight inside a for-each-node loop, a dim
mismatch between two chained typed linears — surfaced as a bare ``KeyError``
deep inside ``passes.lower_program`` or a shape error under ``jit``. This
module rejects such programs *at construction time* with a named
``ProgramValidationError`` carrying the statement index and (when the
program was traced by the frontend) the authoring source line.

Two entry points:

* ``check_var_refs`` — the cheap referential subset (undefined edge/node
  vars, including the ``EdgeSoftmax``/``NodeAggregate`` operands).
  ``lower_program`` runs it on every input program.
* ``validate_program`` — the full pass: referential checks plus loop-domain
  rules (edge data in node loops and vice versa), weight-index legality,
  and best-effort dim inference across ``@`` / ``dot`` / elementwise ops.
  The tracing frontend runs it on every traced model.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.ir import inter_op as I


class ProgramValidationError(ValueError):
    """A Program is malformed. Message carries the program name, the
    statement index, and — for DSL-traced programs — the authoring model
    line (``file:line: code``)."""

    def __init__(self, message: str, *, program: Optional[str] = None,
                 stmt_index: Optional[int] = None,
                 source: Optional[I.SourceLoc] = None):
        where = []
        if program:
            where.append(f"model '{program}'")
        if stmt_index is not None:
            where.append(f"statement {stmt_index}")
        if source is not None:
            where.append(f"[{source}]")
        prefix = " ".join(where)
        super().__init__(f"{prefix}: {message}" if prefix else message)
        self.program = program
        self.stmt_index = stmt_index
        self.source = source


_ALLOWED_INDEXED_BY = (None, "etype", "ntype", "ntype_src", "ntype_dst")
_EDGE_STMT_INDEXED_BY = (None, "etype")


class _Validator:
    def __init__(self, prog: I.Program, shapes: bool, domains: bool):
        self.prog = prog
        self.shapes = shapes
        self.domains = domains
        self.edge_vars: Dict[str, Optional[int]] = {}
        self.node_vars: Dict[str, Optional[int]] = {}
        self.inputs: Dict[str, int] = {}    # named input feature -> dim
        self.i = 0

    # ------------------------------------------------------------------
    def fail(self, message: str) -> None:
        src = self.prog.source or {}
        raise ProgramValidationError(
            message, program=self.prog.name, stmt_index=self.i,
            source=src.get(self.i))

    def need_edge_var(self, name: str, what: str) -> Optional[int]:
        if name in self.node_vars:
            self.fail(f"{what} requires an edge var, but n[{name}] is a "
                      f"node var (produced by a for-each-node statement)")
        if name not in self.edge_vars:
            have = sorted(self.edge_vars) or ["<none>"]
            self.fail(f"{what} reads undefined edge var '{name}'; "
                      f"edge vars defined so far: {', '.join(have)}")
        return self.edge_vars[name]

    # ------------------------------------------------------------------
    def run(self) -> None:
        for i, s in enumerate(self.prog.stmts):
            self.i = i
            if isinstance(s, I.EdgeCompute):
                self.check_expr(s.expr, domain="edge")
                self.edge_vars[s.out] = (
                    self.infer(s.expr) if self.shapes else None)
            elif isinstance(s, I.NodeCompute):
                self.check_expr(s.expr, domain="node")
                self.node_vars[s.out] = (
                    self.infer(s.expr) if self.shapes else None)
            elif isinstance(s, I.EdgeSoftmax):
                self.need_edge_var(s.src, "edge_softmax")
                self.edge_vars[s.out] = 1
            elif isinstance(s, I.NodeAggregate):
                d = self.need_edge_var(s.msg, "aggregate message")
                if s.scale is not None:
                    self.need_edge_var(s.scale, "aggregate scale")
                if s.reduce not in ("sum", "mean"):
                    self.fail(f"unknown aggregate reduce {s.reduce!r}; "
                              f"pick 'sum' or 'mean'")
                self.node_vars[s.out] = d
        for out in self.prog.outputs:
            if out not in self.edge_vars and out not in self.node_vars:
                raise ProgramValidationError(
                    f"output '{out}' is never assigned",
                    program=self.prog.name)

    # ------------------------------------------------------------------
    def check_expr(self, e: I.Expr, domain: str,
                   linear_x: bool = False) -> None:
        if isinstance(e, I.EdgeVar):
            if domain == "node" and self.domains:
                self.fail(f"edge var e[{e.name}] read in a for-each-node"
                          f" statement; aggregate it first")
            else:
                # referential check runs in both modes (and in both
                # domains): an undefined edge var must never reach codegen
                self.need_edge_var(e.name, "expression")
        elif isinstance(e, I.NodeVar):
            if domain == "edge" and self.domains:
                self.fail(f"node var n[{e.name}] read in a for-each-edge "
                          f"statement; use e.src[...] / e.dst[...]")
            if domain == "node" and e.name not in self.node_vars:
                have = sorted(self.node_vars) or ["<none>"]
                self.fail(f"undefined node var '{e.name}'; node vars "
                          f"defined so far: {', '.join(have)}")
        elif isinstance(e, I.NodeFeature):
            if domain == "edge" and self.domains:
                self.fail(f"node data n.{e.name} read in a for-each-edge "
                          f"statement; use e.src[{e.name!r}] or "
                          f"e.dst[{e.name!r}]")
            if domain == "node" and self.domains and not linear_x:
                # the lowering has no elementwise read of a raw input
                # feature (it would fall back past the executor), and this
                # shape is almost always a typo'd produced-var name
                have = sorted(self.node_vars) or ["<none>"]
                self.fail(f"input n.{e.name} can only feed a linear ('@') "
                          f"in a for-each-node statement; if you meant a "
                          f"produced node var, check the name (node vars "
                          f"defined so far: {', '.join(have)})")
        elif isinstance(e, (I.SrcFeature, I.DstFeature)):
            if domain == "node" and self.domains:
                end = "src" if isinstance(e, I.SrcFeature) else "dst"
                self.fail(f"edge endpoint data e.{end}.{e.name} read in a "
                          f"for-each-node statement")
        elif isinstance(e, I.Weight) and self.domains:
            if e.indexed_by not in _ALLOWED_INDEXED_BY:
                self.fail(f"weight '{e.name}' has unknown "
                          f"indexed_by={e.indexed_by!r}; pick one of "
                          f"{_ALLOWED_INDEXED_BY}")
            if domain == "edge" and e.indexed_by not in _EDGE_STMT_INDEXED_BY:
                self.fail(
                    f"weight '{e.name}' indexed_by={e.indexed_by!r} cannot "
                    f"be used in a for-each-edge statement (the lowering "
                    f"has no edgewise {e.indexed_by}-segmented GEMM); "
                    f"index it by 'etype', or apply it in a for-each-node "
                    f"statement and read the result via e.src/e.dst")
            if domain == "node" and e.indexed_by == "etype":
                self.fail(f"etype-indexed weight '{e.name}' used in a "
                          f"for-each-node statement (no edge type is in "
                          f"scope); move the computation onto the edges")
        if isinstance(e, (I.TypedLinear, I.Linear)):
            # only the *direct* GEMM input may be a raw node feature
            self.check_expr(e.x, domain, linear_x=True)
            self.check_expr(e.weight, domain)
        else:
            for c in e.children():
                self.check_expr(c, domain)

    # ------------------------------------------------------------------
    # best-effort dim inference (None = unknown; errors only on known-known
    # conflicts, so partially-annotated programs never false-positive)
    # ------------------------------------------------------------------
    def named_dim(self, name: str) -> Optional[int]:
        if name in self.node_vars:
            return self.node_vars[name]
        return self.inputs.get(name)

    def bind_named(self, e: I.Expr, d: int) -> None:
        if isinstance(e, (I.NodeFeature, I.SrcFeature, I.DstFeature)):
            if e.name in self.node_vars:
                return
            prev = self.inputs.get(e.name)
            if prev is not None and prev != d:
                self.fail(f"input feature '{e.name}' used with inconsistent"
                          f" dims: {prev} vs {d}")
            self.inputs[e.name] = d

    def infer(self, e: I.Expr) -> Optional[int]:
        if isinstance(e, (I.NodeFeature, I.SrcFeature, I.DstFeature)):
            return self.named_dim(e.name)
        if isinstance(e, I.EdgeVar):
            return self.edge_vars.get(e.name)
        if isinstance(e, I.NodeVar):
            return self.node_vars.get(e.name)
        if isinstance(e, I.Weight):
            return e.shape[0] if len(e.shape) == 1 else None
        if isinstance(e, I.Scalar):
            return 1
        if isinstance(e, (I.TypedLinear, I.Linear)):
            xd = self.infer(e.x)
            w = e.weight
            if len(w.shape) >= 2:
                if xd is None:
                    self.bind_named(e.x, w.shape[0])
                elif xd != w.shape[0]:
                    self.fail(f"dim mismatch in '@': left operand "
                              f"({I.render_expr(e.x)}) has dim {xd} but "
                              f"weight '{w.name}' expects {w.shape[0]}")
                return w.shape[-1]
            return None
        if isinstance(e, I.DotProduct):
            ad, bd = self.infer(e.a), self.infer(e.b)
            if ad is None and bd is not None:
                self.bind_named(e.a, bd)
            if bd is None and ad is not None:
                self.bind_named(e.b, ad)
            if ad is not None and bd is not None and ad != bd:
                self.fail(f"dot() operand dim mismatch: "
                          f"{I.render_expr(e.a)} has dim {ad} but "
                          f"{I.render_expr(e.b)} has dim {bd}")
            return 1
        if isinstance(e, I.Binary):
            ad, bd = self.infer(e.a), self.infer(e.b)
            if (ad is not None and bd is not None and ad != bd
                    and 1 not in (ad, bd)):
                self.fail(f"'{e.op}' operand dim mismatch: "
                          f"{I.render_expr(e.a)} has dim {ad} but "
                          f"{I.render_expr(e.b)} has dim {bd}")
            for d in (ad, bd):
                if d is not None and d != 1:
                    return d
            # an unknown operand broadcast against a scalar stays unknown
            # (x * 2.0 must not collapse to dim 1)
            if ad is None or bd is None:
                return None
            return 1
        if isinstance(e, I.Unary):
            return self.infer(e.a)
        if isinstance(e, I.Concat):
            dims = [self.infer(p) for p in e.parts]
            if any(d is None for d in dims):
                return None
            return sum(dims)
        return None


def validate_program(prog: I.Program) -> I.Program:
    """Full validation: referential + loop-domain + weight-index + dim
    checks. Raises ``ProgramValidationError``; returns ``prog`` unchanged
    so it can be used inline."""
    _Validator(prog, shapes=True, domains=True).run()
    return prog


def check_var_refs(prog: I.Program) -> I.Program:
    """Referential subset only (undefined edge/node vars, incl. the
    ``EdgeSoftmax``/``NodeAggregate`` operands). Run by ``lower_program``
    on every input, replacing the opaque downstream ``KeyError``s."""
    _Validator(prog, shapes=False, domains=False).run()
    return prog
