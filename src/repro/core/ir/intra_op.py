"""Hector intra-operator level IR (paper §3.3).

Every kernel the code generator emits derives from one of two templates:

* ``GemmSpec`` — the GEMM template ``Y[S] = X[G] × W[T]`` (Algorithm 1):
  tiled matmul with pluggable gather scheme on X, type-indexed weight
  selection, scatter scheme on Y, optional fused per-row scalar (the paper's
  "per-row scalar applied to the tiles of matrix A", §3.4.1), transpose
  flags, and an operator-specific schedule (tile sizes, coarsening factor).

* ``TraversalSpec`` — the traversal template (Algorithm 2): fused edgewise /
  nodewise statements executed inside a canonical loop nest, with an
  adjacency access scheme (COO row-index vs CSR binary search on GPU; on TPU
  the scheme selects between dst-sorted segment accumulation and gather-based
  access — see DESIGN.md §3).

Specs carry *all* information needed to emit code; lowering from the
inter-operator IR fills them in (passes.py) and codegen.py materializes JAX
callables / Pallas kernel instantiations from them.
"""
from __future__ import annotations

import dataclasses
import enum
import hashlib
from typing import List, Optional, Tuple

from repro.core.ir import inter_op as iop


class Preference(enum.IntEnum):
    """Operator-instance preference levels for selection (§3.4.2)."""

    FALLBACK = 0      # plain jnp op-by-op (the "PyTorch fallback")
    TRAVERSAL = 1     # traversal-template instance
    GEMM = 2          # GEMM-template instance


class GatherScheme(enum.Enum):
    IDENTITY = "identity"          # X rows already in canonical order
    BY_EDGE_SRC = "edge_src"       # gather node rows via edge src list
    BY_EDGE_DST = "edge_dst"       # gather node rows via edge dst list
    BY_UNIQUE_SRC = "unique_src"   # gather node rows via compact map
    BY_NODE = "node"               # nodewise op: identity over nodes


class ScatterScheme(enum.Enum):
    IDENTITY = "identity"          # contiguous segment output
    BY_EDGE = "edge"               # scatter to canonical edge order
    BY_UNIQUE = "unique"           # scatter to compact rows


class TypeIndex(enum.Enum):
    NONE = "none"          # untyped (single-relation degenerate GEMM)
    ETYPE = "etype"        # weight indexed by edge type
    NTYPE = "ntype"        # weight indexed by node type


@dataclasses.dataclass
class GemmSchedule:
    """Operator-specific schedule knobs (§3.4.1).

    TPU adaptation: ``tile_rows``/``tile_cols`` are VMEM block shapes (MXU
    wants multiples of 128 on the minor dim); ``coarsening`` multiplies the
    rows each grid step processes, trading VMEM for fewer grid iterations
    (the analogue of the paper's thread coarsening factor in {2, 4}).
    """

    tile_rows: int = 128
    tile_cols: int = 128
    tile_k: int = 128
    coarsening: int = 1            # in {1, 2, 4}

    @property
    def block_rows(self) -> int:
        return self.tile_rows * self.coarsening


@dataclasses.dataclass
class GemmSpec:
    """One GEMM-template instance. Y[S] = act( scale ⊙ (X[G] @ W[T]) )."""

    kid: str                               # unique kernel id (FuncName<kid>)
    x_source: str                          # tensor name: node feature / edge var
    gather: GatherScheme
    weight: str                            # weight param name
    type_index: TypeIndex
    seg_ptr: str                           # which segment ptr: 'etype_ptr' | 'unique_etype_ptr' | 'ntype_ptr'
    out: str                               # output var name
    scatter: ScatterScheme
    per_row_scale: Optional[str] = None    # fused epilogue scalar (edge var)
    transpose_w: bool = False
    out_cols: int = 0                      # N dim of the GEMM
    schedule: GemmSchedule = dataclasses.field(default_factory=GemmSchedule)
    preference: Preference = Preference.GEMM

    def can_fuse_epilogue_scale(self) -> bool:
        """§3.4.2: GEMM instances fuse a consumer that multiplies output rows
        by scalars, provided both live in the same (edge) loop."""
        return self.per_row_scale is None


# ---------------------------------------------------------------------------
# traversal template
# ---------------------------------------------------------------------------
class LoopDomain(enum.Enum):
    EDGES = "edges"
    NODES = "nodes"


@dataclasses.dataclass
class TraversalStmt:
    """A statement placed in the traversal loop nest.

    ``kind`` in:
      'elementwise'  out[i] = f(ins[i]...)          (innermost, hoistable)
      'segment_max'  out[dst] = max over incoming    (partial-result agg)
      'segment_sum'  out[dst] = sum over incoming
      'gather_dst'   out[i] = in[dst[i]]             (dst-indexed read)
      'gather_unique' out[i] = in[edge_to_unique[i]] (compact-layout read)
    """

    kind: str
    out: str
    ins: Tuple[str, ...]
    op: Optional[str] = None          # for elementwise: exp/div/mul/leaky_relu/...
    alpha: float = 0.01
    scale: Optional[str] = None       # for segment_sum: per-edge scalar
    hoist_level: int = 0              # loop level after hoisting (§3.4.1)


@dataclasses.dataclass
class TraversalSpec:
    """One traversal-template instance: a fused region of statements."""

    kid: str
    domain: LoopDomain
    stmts: List[TraversalStmt]
    adjacency: str = "dst_csr"        # access scheme: 'dst_csr' | 'coo'
    preference: Preference = Preference.TRAVERSAL
    partial_aggregation: bool = True  # warp/VMEM partial sums before global


@dataclasses.dataclass
class FallbackSpec:
    """Ops the lowering leaves to the framework (lowest preference)."""

    kid: str
    stmt: object                       # the original inter-op Stmt
    preference: Preference = Preference.FALLBACK


@dataclasses.dataclass
class WeightProductSpec:
    """Hoisted weight-by-weight product from linear-operator reordering
    (§3.2.3): computed once per relation via BMM, outside edge loops."""

    kid: str
    out: str                           # derived weight name
    w_matrix: str                      # [R, d, f]
    w_vector: str                      # [R, f] (or [R, f, g])
    transpose: bool = True             # W_r @ w_r^T


@dataclasses.dataclass
class Plan:
    """Fully lowered layer: ordered op instances + bookkeeping."""

    name: str
    ops: List[object]                  # GemmSpec | TraversalSpec | FallbackSpec | WeightProductSpec
    outputs: List[str]
    layouts: dict                      # var -> iop.Layout
    weights: dict                      # name -> iop.Weight

    def gemm_count(self) -> int:
        return sum(isinstance(o, GemmSpec) for o in self.ops)

    def traversal_count(self) -> int:
        return sum(isinstance(o, TraversalSpec) for o in self.ops)

    def fallback_count(self) -> int:
        return sum(isinstance(o, FallbackSpec) for o in self.ops)

    def describe(self) -> str:
        lines = [f"Plan<{self.name}>"]
        for o in self.ops:
            if isinstance(o, GemmSpec):
                lines.append(
                    f"  GEMM<{o.kid}> {o.out} = {o.x_source}[{o.gather.value}]"
                    f" @ {o.weight}[{o.type_index.value}]"
                    + (f" * {o.per_row_scale}" if o.per_row_scale else "")
                    + f" -> scatter:{o.scatter.value} tile={o.schedule.tile_rows}x"
                    f"{o.schedule.tile_cols} coarsen={o.schedule.coarsening}"
                )
            elif isinstance(o, TraversalSpec):
                ops = ",".join(s.kind + (f"({s.op})" if s.op else "") for s in o.stmts)
                lines.append(f"  TRAV<{o.kid}> [{o.domain.value}/{o.adjacency}] {ops}")
            elif isinstance(o, WeightProductSpec):
                lines.append(f"  WPROD<{o.kid}> {o.out} = {o.w_matrix} @ {o.w_vector}^T")
            else:
                lines.append(f"  FALLBACK<{o.kid}> {type(o.stmt).__name__}")
        return "\n".join(lines)

    def fingerprint(self) -> str:
        """Structural-identity hash of the lowered plan: the rendered op
        sequence plus the layout and weight tables. Plans lowered from
        structurally identical programs (DSL-traced or hand-built)
        fingerprint identically; the compiled executors fold this into
        their compile-cache keys."""
        parts = [
            self.describe(),
            repr(self.ops),   # full spec dataclass reprs (describe elides some fields)
            repr(sorted((k, v.value) for k, v in self.layouts.items())),
            repr(sorted((k, (tuple(w.shape), w.indexed_by))
                        for k, w in self.weights.items())),
        ]
        return hashlib.sha256("\n".join(parts).encode()).hexdigest()[:16]
