"""Inter-operator level transformation passes (paper §3.2.3–§3.2.5).

Implemented passes:

* ``reorder_linear_ops``   — linear-operator reordering (§3.2.3). Rewrites
  ``dot(typed_linear(x, W), w_vec[etype])`` into
  ``typed_linear(x, (W @ w_vec^T)[etype])``: the weight-by-weight product is
  hoisted out of the edge loop and computed once per relation (BMM), shrinking
  the edgewise GEMM factor from #edges×d×f to #edges×d×1.

* ``apply_compact_materialization`` — compact materialization (§3.2.2).
  Marks every edgewise assignment whose RHS depends only on (source node,
  edge type) with the COMPACT layout; the lowering then materializes one row
  per unique (src, etype) pair and readers go through ``edge_to_unique``.

* ``lower_program``        — the 3-pass greedy lowering (§3.2.5): GEMM
  instances first, traversal instances next (after loop canonicalization and
  fusion), framework fallback last; plus the fusion legality rules of §3.4.2
  (GEMM + per-row-scalar epilogue; traversal regions in the same loop nest).
"""
from __future__ import annotations

import dataclasses
from typing import Collection, Dict, List, Optional, Tuple

from repro.core.ir import inter_op as I
from repro.core.ir import intra_op as O
from repro.core.ir.validate import ProgramValidationError, check_var_refs  # noqa: F401 (re-exported)


# ---------------------------------------------------------------------------
# linear operator reordering (§3.2.3)
# ---------------------------------------------------------------------------
def _resolve(expr: I.Expr, defs: Dict[str, I.Expr]) -> I.Expr:
    """Look through EdgeVar references to the defining expression."""
    seen = set()
    while isinstance(expr, I.EdgeVar) and expr.name in defs:
        if expr.name in seen:  # cycle guard
            break
        seen.add(expr.name)
        expr = defs[expr.name]
    return expr


def reorder_linear_ops(prog: I.Program) -> Tuple[I.Program, List[O.WeightProductSpec]]:
    """Apply the reordering rewrite wherever it creates a weight×weight op.

    Profitability (paper): the rewrite reduces one GEMM factor from the
    number of edges to the hidden dimension, so it is applied whenever the
    pattern matches (the paper implements exactly this policy).
    """
    prog = prog.clone()
    defs: Dict[str, I.Expr] = {}
    for s in prog.stmts:
        if isinstance(s, I.EdgeCompute):
            defs[s.out] = s.expr

    wprods: List[O.WeightProductSpec] = []
    new_stmts: List[I.Stmt] = []
    counter = 0
    for s in prog.stmts:
        if isinstance(s, I.EdgeCompute) and isinstance(s.expr, I.DotProduct):
            dot = s.expr
            lhs = _resolve(dot.a, defs)
            rhs = dot.b
            if (
                isinstance(lhs, I.TypedLinear)
                and isinstance(lhs.x, (I.SrcFeature, I.DstFeature))
                and isinstance(rhs, I.Weight)
                and rhs.indexed_by == "etype"
                and lhs.weight.indexed_by == "etype"
                and len(rhs.shape) == 1
            ):
                counter += 1
                composed_name = f"_wprod{counter}__{lhs.weight.name}__{rhs.name}"
                wprods.append(
                    O.WeightProductSpec(
                        kid=f"wprod_{counter}",
                        out=composed_name,
                        w_matrix=lhs.weight.name,
                        w_vector=rhs.name,
                        transpose=True,
                    )
                )
                composed = I.Weight(
                    name=composed_name,
                    shape=(lhs.weight.shape[0], 1),
                    indexed_by="etype",
                )
                # (x W_r) · w_r  ->  x (W_r w_r^T): a typed linear with f=1
                new_stmts.append(
                    I.EdgeCompute(out=s.out, expr=I.TypedLinear(lhs.x, composed))
                )
                continue
        new_stmts.append(s)
    prog.stmts = new_stmts
    return prog, wprods


# ---------------------------------------------------------------------------
# compact materialization (§3.2.2)
# ---------------------------------------------------------------------------
def apply_compact_materialization(
    prog: I.Program, only: Optional[Collection[str]] = None
) -> I.Program:
    """Mark compactable edgewise variables with the COMPACT layout.

    Paper applicability condition (§3.2.2): the edgewise operator depends
    only on (source node, edge type) AND its output has shape
    (num_edges, hidden) — i.e. it is a materialized GEMM-template output
    (typed linear), not a scalar traversal product.

    ``only`` restricts the marking to a chosen subset of the eligible vars
    — the per-variable materialization decision of the autotuner (the paper
    applies compaction all-or-nothing per model; Table 5 shows the best
    choice varies, so the tuner decides per variable). Vars outside
    ``only`` stay VANILLA, and a var whose compactable *inputs* were left
    VANILLA is itself no longer eligible (its reads go through per-edge
    rows).
    """
    prog = prog.clone()
    compact_vars: set = set()
    for s in prog.stmts:
        if (
            isinstance(s, I.EdgeCompute)
            and isinstance(s.expr, I.TypedLinear)
            and I.compactable(s.expr, compact_vars)
            and (only is None or s.out in only)
        ):
            prog.layouts[s.out] = I.Layout.COMPACT
            compact_vars.add(s.out)
    return prog


def compactable_edge_vars(prog: I.Program, reorder: bool = True) -> List[str]:
    """Names of the edge vars ``lower_program`` *could* mark COMPACT, after
    the same pre-passes it would run (so the names line up with the plan the
    autotuner will lower). The tuner enumerates its per-var materialization
    space from this list."""
    if reorder:
        prog, _ = reorder_linear_ops(prog)
    prog = flatten_gemms(prog)
    names: List[str] = []
    compact_vars: set = set()
    for s in prog.stmts:
        if (
            isinstance(s, I.EdgeCompute)
            and isinstance(s.expr, I.TypedLinear)
            and I.compactable(s.expr, compact_vars)
        ):
            names.append(s.out)
            compact_vars.add(s.out)
    return names


# ---------------------------------------------------------------------------
# flattening: hoist nested GEMM-eligible subexpressions into statements so
# pass 1 of the lowering can claim them (part of loop canonicalization)
# ---------------------------------------------------------------------------
def flatten_gemms(prog: I.Program) -> I.Program:
    prog = prog.clone()
    new_stmts: List[I.Stmt] = []
    counter = [0]

    def hoist(e: I.Expr, acc: List[I.Stmt], top: bool) -> I.Expr:
        if isinstance(e, (I.TypedLinear, I.Linear)) and not top:
            x = hoist(e.x, acc, top=False)
            counter[0] += 1
            tmp = f"_flat{counter[0]}"
            acc.append(I.EdgeCompute(tmp, dataclasses.replace(e, x=x)))
            return I.EdgeVar(tmp)
        if isinstance(e, I.TypedLinear):
            return dataclasses.replace(e, x=hoist(e.x, acc, top=False))
        if isinstance(e, I.Linear):
            return dataclasses.replace(e, x=hoist(e.x, acc, top=False))
        if isinstance(e, I.DotProduct):
            return I.DotProduct(hoist(e.a, acc, False), hoist(e.b, acc, False))
        if isinstance(e, I.Binary):
            return I.Binary(e.op, hoist(e.a, acc, False), hoist(e.b, acc, False))
        if isinstance(e, I.Unary):
            return I.Unary(e.op, hoist(e.a, acc, False), e.alpha)
        if isinstance(e, I.Concat):
            return I.Concat(tuple(hoist(p, acc, False) for p in e.parts))
        return e

    for s in prog.stmts:
        if isinstance(s, I.EdgeCompute):
            acc: List[I.Stmt] = []
            expr = hoist(s.expr, acc, top=True)
            new_stmts.extend(acc)
            new_stmts.append(I.EdgeCompute(s.out, expr))
        else:
            new_stmts.append(s)
    prog.stmts = new_stmts
    return prog


# ---------------------------------------------------------------------------
# loop canonicalization (§3.2.4) — expand composites so fusion sees loops
# ---------------------------------------------------------------------------
def canonicalize(prog: I.Program) -> I.Program:
    """Expand EdgeSoftmax into its loop form (exp / per-dst reduce / divide).

    Graph-semantic-aware rule: a for-each-edge loop is equivalent to the
    nest over destination nodes × incoming edges, so the expansion stays
    fusable with a following NodeAggregate into one traversal region.

    TPU adaptation note: we emit the max-stabilized softmax (segment-max
    before exp); DGL's edge_softmax — the paper's comparison target — is
    also stabilized.
    """
    prog = prog.clone()
    new_stmts: List[I.Stmt] = []
    for s in prog.stmts:
        if isinstance(s, I.EdgeSoftmax):
            new_stmts.append(_ExpandedSoftmax(out=s.out, src=s.src))
        else:
            new_stmts.append(s)
    prog.stmts = new_stmts
    return prog


@dataclasses.dataclass(frozen=True)
class _ExpandedSoftmax(I.Stmt):
    """Internal canonical form of EdgeSoftmax (a fused traversal region)."""

    out: str
    src: str


# ---------------------------------------------------------------------------
# lowering (§3.2.5): three greedy passes + fusion
# ---------------------------------------------------------------------------
def _gemm_eligible(stmt: I.Stmt, layouts: Dict[str, I.Layout]) -> Optional[O.GemmSpec]:
    """Pass-1 eligibility: typed/untyped linear over node or edge data."""
    if isinstance(stmt, I.EdgeCompute):
        e = stmt.expr
        scale = None
        # fused epilogue: expr = typed_linear(...) * e[scalar]  (§3.4.2 rule 1)
        if (
            isinstance(e, I.Binary)
            and e.op == "mul"
            and isinstance(e.a, I.TypedLinear)
            and isinstance(e.b, I.EdgeVar)
        ):
            scale = e.b.name
            e = e.a
        if isinstance(e, I.TypedLinear) and isinstance(
            e.x, (I.SrcFeature, I.DstFeature, I.EdgeVar)
        ):
            w = e.weight
            compact = layouts.get(stmt.out) == I.Layout.COMPACT
            if isinstance(e.x, I.SrcFeature):
                gather = (
                    O.GatherScheme.BY_UNIQUE_SRC if compact else O.GatherScheme.BY_EDGE_SRC
                )
                xsrc = "node:" + e.x.name
            elif isinstance(e.x, I.DstFeature):
                gather = O.GatherScheme.BY_EDGE_DST
                xsrc = "node:" + e.x.name
            else:
                gather = O.GatherScheme.IDENTITY
                xsrc = "edge:" + e.x.name
            if w.indexed_by == "etype":
                seg = "unique_etype_ptr" if compact else "etype_ptr"
                tindex = O.TypeIndex.ETYPE
            elif w.indexed_by is None:
                seg, tindex = "none", O.TypeIndex.NONE
            else:
                return None
            return O.GemmSpec(
                kid="", x_source=xsrc, gather=gather, weight=w.name,
                type_index=tindex, seg_ptr=seg, out=stmt.out,
                scatter=O.ScatterScheme.IDENTITY, per_row_scale=scale,
                out_cols=w.shape[-1],
            )
        if isinstance(e, I.Linear):
            return O.GemmSpec(
                kid="", x_source=_xsrc_of(e.x), gather=_gather_of(e.x, layouts),
                weight=e.weight.name, type_index=O.TypeIndex.NONE, seg_ptr="none",
                out=stmt.out, scatter=O.ScatterScheme.IDENTITY,
                out_cols=e.weight.shape[-1],
            )
    if isinstance(stmt, I.NodeCompute):
        e = stmt.expr
        if isinstance(e, I.TypedLinear) and isinstance(e.x, (I.NodeFeature, I.NodeVar)):
            w = e.weight
            if w.indexed_by in ("ntype_src", "ntype_dst", "ntype"):
                return O.GemmSpec(
                    kid="", x_source="node:" + e.x.name, gather=O.GatherScheme.BY_NODE,
                    weight=w.name, type_index=O.TypeIndex.NTYPE, seg_ptr="ntype_ptr",
                    out=stmt.out, scatter=O.ScatterScheme.IDENTITY,
                    out_cols=w.shape[-1],
                )
        if isinstance(e, I.Linear) and isinstance(e.x, (I.NodeFeature, I.NodeVar)):
            return O.GemmSpec(
                kid="", x_source="node:" + _name_of(e.x), gather=O.GatherScheme.BY_NODE,
                weight=e.weight.name, type_index=O.TypeIndex.NONE, seg_ptr="none",
                out=stmt.out, scatter=O.ScatterScheme.IDENTITY,
                out_cols=e.weight.shape[-1],
            )
    return None


def _name_of(x: I.Expr) -> str:
    if isinstance(x, (I.NodeFeature, I.SrcFeature, I.DstFeature)):
        return x.name
    if isinstance(x, (I.EdgeVar, I.NodeVar)):
        return x.name
    raise ValueError(f"unnamed expr {x}")


def _xsrc_of(x: I.Expr) -> str:
    if isinstance(x, (I.NodeFeature, I.NodeVar)):
        return "node:" + _name_of(x)
    if isinstance(x, I.SrcFeature):
        return "node:" + x.name
    return "edge:" + _name_of(x)


def _gather_of(x: I.Expr, layouts) -> O.GatherScheme:
    if isinstance(x, I.SrcFeature):
        return O.GatherScheme.BY_EDGE_SRC
    if isinstance(x, (I.NodeFeature, I.NodeVar)):
        return O.GatherScheme.BY_NODE
    return O.GatherScheme.IDENTITY


# elementwise expression -> traversal statements -------------------------------
def _expr_to_traversal(
    out: str, e: I.Expr, layouts: Dict[str, I.Layout], tmp_prefix: str
) -> Optional[List[O.TraversalStmt]]:
    """Flatten an edgewise elementwise expression tree into traversal stmts.

    Returns None if the expression contains anything non-elementwise."""
    stmts: List[O.TraversalStmt] = []
    counter = [0]

    def emit(e: I.Expr) -> Optional[str]:
        if isinstance(e, I.EdgeVar):
            if layouts.get(e.name) == I.Layout.COMPACT:
                # compact-layout read: indirection through edge_to_unique
                counter[0] += 1
                t = f"{tmp_prefix}_g{counter[0]}"
                stmts.append(O.TraversalStmt("gather_unique", t, (e.name,)))
                return t
            return e.name
        if isinstance(e, I.SrcFeature):
            counter[0] += 1
            t = f"{tmp_prefix}_s{counter[0]}"
            stmts.append(O.TraversalStmt("gather_src", t, ("node:" + e.name,)))
            return t
        if isinstance(e, I.DstFeature):
            counter[0] += 1
            t = f"{tmp_prefix}_d{counter[0]}"
            stmts.append(O.TraversalStmt("gather_dst", t, ("node:" + e.name,)))
            return t
        if isinstance(e, I.NodeVar):
            return "node:" + e.name
        if isinstance(e, I.Scalar):
            return f"scalar:{e.value}"
        if isinstance(e, I.Unary):
            a = emit(e.a)
            if a is None:
                return None
            counter[0] += 1
            t = f"{tmp_prefix}_u{counter[0]}"
            stmts.append(O.TraversalStmt("elementwise", t, (a,), op=e.op, alpha=e.alpha))
            return t
        if isinstance(e, I.Binary):
            a, b = emit(e.a), emit(e.b)
            if a is None or b is None:
                return None
            counter[0] += 1
            t = f"{tmp_prefix}_b{counter[0]}"
            stmts.append(O.TraversalStmt("elementwise", t, (a, b), op=e.op))
            return t
        if isinstance(e, I.DotProduct):
            a, b = emit(e.a), emit(e.b)
            if a is None or b is None:
                return None
            counter[0] += 1
            t = f"{tmp_prefix}_dp{counter[0]}"
            stmts.append(O.TraversalStmt("rowdot", t, (a, b)))
            return t
        if isinstance(e, I.Concat):
            parts = [emit(p) for p in e.parts]
            if any(p is None for p in parts):
                return None
            counter[0] += 1
            t = f"{tmp_prefix}_c{counter[0]}"
            stmts.append(O.TraversalStmt("concat", t, tuple(parts)))
            return t
        if isinstance(e, I.Weight) and e.indexed_by == "etype" and len(e.shape) == 1:
            # per-edge-type vector broadcast onto edges
            counter[0] += 1
            t = f"{tmp_prefix}_w{counter[0]}"
            stmts.append(O.TraversalStmt("gather_etype_weight", t, (e.name,)))
            return t
        return None

    res = emit(e)
    if res is None:
        return None
    # rename the final temp to the real output
    last = stmts[-1]
    stmts[-1] = dataclasses.replace(last, out=out)
    return stmts


def lower_program(
    prog: I.Program,
    reorder: bool = True,
    compact: bool = True,
    compact_vars: Optional[Collection[str]] = None,
) -> O.Plan:
    """Full §3.2.5 pipeline: optimize, canonicalize, 3-pass greedy lowering.

    ``compact_vars`` (from the autotuner's materialization decisions)
    overrides the all-or-nothing ``compact`` flag with an explicit per-var
    COMPACT set; names must come from ``compactable_edge_vars``.

    Malformed programs (e.g. an ``EdgeSoftmax``/``NodeAggregate`` reading
    an edge var nobody wrote) raise ``ProgramValidationError`` naming the
    missing var and the statement index, instead of a bare ``KeyError``
    deep inside the lowering or the generated code.
    """
    check_var_refs(prog)
    weights = dict(prog.weights())
    wprods: List[O.WeightProductSpec] = []
    if reorder:
        prog, wprods = reorder_linear_ops(prog)
        weights.update(prog.weights())
    prog = flatten_gemms(prog)
    if compact_vars is not None:
        prog = apply_compact_materialization(prog, only=compact_vars)
    elif compact:
        prog = apply_compact_materialization(prog)
    prog = canonicalize(prog)
    layouts = dict(prog.layouts)

    ops: List[object] = list(wprods)
    kid = [0]

    def next_kid(prefix: str) -> str:
        kid[0] += 1
        return f"{prefix}_{kid[0]}"

    # --- pass 1: GEMM-template instances (highest preference) -------------
    lowered: List[Optional[object]] = [None] * len(prog.stmts)
    for i, s in enumerate(prog.stmts):
        g = _gemm_eligible(s, layouts)
        if g is not None:
            g.kid = next_kid("gemm")
            lowered[i] = g

    # --- pass 2: traversal-template instances, fused greedily -------------
    pending: List[O.TraversalStmt] = []

    def flush(acc: List[object]):
        if pending:
            acc.append(
                O.TraversalSpec(kid=next_kid("trav"), domain=O.LoopDomain.EDGES,
                                stmts=list(pending))
            )
            pending.clear()

    seq: List[object] = []
    for i, s in enumerate(prog.stmts):
        if lowered[i] is not None:
            flush(seq)
            seq.append(lowered[i])
            continue
        if isinstance(s, _ExpandedSoftmax):
            pending.extend([
                O.TraversalStmt("segment_max", f"_{s.out}_max", (s.src,)),
                O.TraversalStmt("gather_dst_var", f"_{s.out}_maxe", (f"_{s.out}_max",)),
                O.TraversalStmt("elementwise", f"_{s.out}_sh", (s.src, f"_{s.out}_maxe"), op="sub"),
                O.TraversalStmt("elementwise", f"_{s.out}_exp", (f"_{s.out}_sh",), op="exp"),
                O.TraversalStmt("segment_sum", f"_{s.out}_den", (f"_{s.out}_exp",)),
                O.TraversalStmt("gather_dst_var", f"_{s.out}_dene", (f"_{s.out}_den",)),
                O.TraversalStmt("elementwise", s.out, (f"_{s.out}_exp", f"_{s.out}_dene"), op="div"),
            ])
            continue
        if isinstance(s, I.NodeAggregate):
            pending.append(
                O.TraversalStmt("segment_sum" if s.reduce in ("sum", "mean") else s.reduce,
                                s.out, (s.msg,), scale=s.scale,
                                op="mean" if s.reduce == "mean" else None)
            )
            continue
        if isinstance(s, I.EdgeCompute):
            tstmts = _expr_to_traversal(s.out, s.expr, layouts, f"_t{i}")
            if tstmts is not None:
                pending.extend(tstmts)
                continue
        if isinstance(s, I.NodeCompute):
            tstmts = _expr_to_traversal(s.out, s.expr, layouts, f"_t{i}")
            if tstmts is not None:
                flush(seq)
                seq.append(O.TraversalSpec(kid=next_kid("trav"),
                                           domain=O.LoopDomain.NODES,
                                           stmts=tstmts))
                continue
        # --- pass 3: framework fallback -----------------------------------
        flush(seq)
        seq.append(O.FallbackSpec(kid=next_kid("fb"), stmt=s))
    flush(seq)
    ops.extend(seq)

    return O.Plan(name=prog.name, ops=ops, outputs=list(prog.outputs),
                  layouts=layouts, weights=weights)
