"""Hector inter-operator level IR (paper §3.2).

The IR expresses RGNN model semantics as for-each-edge / for-each-node loops
over typed graph elements, **without** dictating data layout. Constructs map
1:1 onto Table 2 of the paper:

  node/edge iterators        -> ``ForEachEdge`` / ``ForEachNode`` statements
  ``e.src``, ``e.dst``       -> ``SrcFeature`` / ``DstFeature`` accessors
  ``W[e.etype]``             -> ``Weight(name, indexed_by="etype")``
  input data ``n.feature``   -> ``NodeFeature``
  produced data ``e["att"]`` -> ``EdgeVar`` / ``NodeVar`` (layout decided later)
  GEMM-eligible ops          -> ``TypedLinear``, ``Linear``
  GEMM-ineligible ops        -> ``DotProduct``, elementwise ``Unary``/``Binary``
  manipulation               -> ``Concat``, reshape is implicit

A model is a ``Program``: an ordered list of statements. Layout choices
(vanilla vs compact materialization per edge variable) are annotations kept
*next to* the program (``Program.layouts``), never inside expressions —
that decoupling is the paper's central design point.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence, Tuple


class Layout(enum.Enum):
    """Materialization choice for an edge-associated variable (§3.2.2)."""

    VANILLA = "vanilla"     # one row per edge (etype-sorted canonical order)
    COMPACT = "compact"     # one row per unique (src node, etype) pair


# ---------------------------------------------------------------------------
# expressions
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Expr:
    def free_inputs(self) -> Tuple["Expr", ...]:
        return ()

    def children(self) -> Tuple["Expr", ...]:
        return ()


@dataclasses.dataclass(frozen=True)
class NodeFeature(Expr):
    """Input node feature tensor [N, d]."""
    name: str = "feature"


@dataclasses.dataclass(frozen=True)
class SrcFeature(Expr):
    """``e.src.<name>`` — gather of node data by edge source."""
    name: str = "feature"


@dataclasses.dataclass(frozen=True)
class DstFeature(Expr):
    """``e.dst.<name>`` — gather of node data by edge destination."""
    name: str = "feature"


@dataclasses.dataclass(frozen=True)
class EdgeVar(Expr):
    """``e["name"]`` — produced edgewise data."""
    name: str


@dataclasses.dataclass(frozen=True)
class NodeVar(Expr):
    """``n["name"]`` — produced nodewise data."""
    name: str


@dataclasses.dataclass(frozen=True)
class Weight(Expr):
    """Model weight, optionally indexed by a type dimension.

    ``indexed_by`` in {None, "etype", "ntype_src", "ntype_dst"}; shape is the
    *per-type* shape (e.g. (d_in, d_out) for a typed linear).
    """
    name: str
    shape: Tuple[int, ...]
    indexed_by: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class TypedLinear(Expr):
    """``x @ W[type]`` — the edgewise/nodewise typed linear layer (§2.3)."""
    x: Expr
    weight: Weight

    def children(self):
        return (self.x, self.weight)


@dataclasses.dataclass(frozen=True)
class Linear(Expr):
    """Untyped linear ``x @ W`` (single relation degenerate case, §3.7)."""
    x: Expr
    weight: Weight

    def children(self):
        return (self.x, self.weight)


@dataclasses.dataclass(frozen=True)
class DotProduct(Expr):
    """Edgewise dot product -> scalar per edge (GEMM-ineligible, §3.3.1)."""
    a: Expr
    b: Expr

    def children(self):
        return (self.a, self.b)


@dataclasses.dataclass(frozen=True)
class Binary(Expr):
    op: str  # add | sub | mul | div
    a: Expr
    b: Expr

    def children(self):
        return (self.a, self.b)


@dataclasses.dataclass(frozen=True)
class Unary(Expr):
    op: str  # exp | leaky_relu | relu | sigmoid | neg | tanh
    a: Expr
    alpha: float = 0.01  # leaky_relu slope

    def children(self):
        return (self.a,)


@dataclasses.dataclass(frozen=True)
class Concat(Expr):
    parts: Tuple[Expr, ...]

    def children(self):
        return tuple(self.parts)


@dataclasses.dataclass(frozen=True)
class Scalar(Expr):
    value: float


# ---------------------------------------------------------------------------
# statements
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Stmt:
    pass


@dataclasses.dataclass(frozen=True)
class EdgeCompute(Stmt):
    """``for e in g.edges(): e[out] = expr``"""
    out: str
    expr: Expr


@dataclasses.dataclass(frozen=True)
class EdgeSoftmax(Stmt):
    """``e[out] = softmax_{edges sharing e.dst}(e[src])`` (Listing 1 lines 1-9).

    Kept as a composite statement; canonicalization may expand it into the
    exp / per-dst-sum / divide loop nest, and the traversal template re-fuses
    it (§3.2.4 loop transformation round-trips this).
    """
    out: str
    src: str


@dataclasses.dataclass(frozen=True)
class NodeAggregate(Stmt):
    """``for n: n[out] = reduce_{e in n.incoming_edges()} scale * e[msg]``.

    ``scale`` (optional edge scalar variable, e.g. attention) multiplies each
    message row; reduce is 'sum' or 'mean' (mean divides by in-degree, the
    RGCN 1/c_{v,r} normalizer folded per destination).
    """
    out: str
    msg: str
    scale: Optional[str] = None
    reduce: str = "sum"


@dataclasses.dataclass(frozen=True)
class NodeCompute(Stmt):
    """``for n in g.nodes(): n[out] = expr`` (expr over node data)."""
    out: str
    expr: Expr


# ---------------------------------------------------------------------------
# program
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Program:
    """An RGNN layer as inter-operator IR + decoupled layout annotations."""

    stmts: List[Stmt]
    outputs: List[str]                       # node/edge vars returned
    layouts: Dict[str, Layout] = dataclasses.field(default_factory=dict)
    name: str = "rgnn_layer"

    def layout_of(self, var: str) -> Layout:
        return self.layouts.get(var, Layout.VANILLA)

    def clone(self) -> "Program":
        return Program(list(self.stmts), list(self.outputs),
                       dict(self.layouts), self.name)

    def weights(self) -> Dict[str, Weight]:
        out: Dict[str, Weight] = {}

        def visit(e: Expr):
            if isinstance(e, Weight):
                out[e.name] = e
            for c in e.children():
                visit(c)

        for s in self.stmts:
            if isinstance(s, (EdgeCompute, NodeCompute)):
                visit(s.expr)
        return out


# ---------------------------------------------------------------------------
# expression analysis helpers used by the passes
# ---------------------------------------------------------------------------
def expr_deps(e: Expr) -> set:
    """Set of dependency tags: 'src', 'dst', 'etype', 'ntype', edge/node vars."""
    deps: set = set()

    def visit(x: Expr):
        if isinstance(x, SrcFeature):
            deps.add("src")
        elif isinstance(x, DstFeature):
            deps.add("dst")
        elif isinstance(x, EdgeVar):
            deps.add(("evar", x.name))
        elif isinstance(x, NodeVar):
            deps.add(("nvar", x.name))
        elif isinstance(x, Weight) and x.indexed_by == "etype":
            deps.add("etype")
        elif isinstance(x, Weight) and x.indexed_by in ("ntype_src", "ntype_dst"):
            deps.add("ntype")
            deps.add("src" if x.indexed_by == "ntype_src" else "dst")
        for c in x.children():
            visit(c)

    visit(e)
    return deps


def compactable(e: Expr, compact_vars: set) -> bool:
    """True if an edgewise expression depends only on (src, etype) — the
    compact-materialization applicability condition (§3.2.2). Reading another
    edge var is fine iff that var is itself compact."""
    deps = expr_deps(e)
    if "dst" in deps:
        return False
    for d in deps:
        if isinstance(d, tuple) and d[0] == "evar" and d[1] not in compact_vars:
            return False
    return True
