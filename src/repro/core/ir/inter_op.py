"""Hector inter-operator level IR (paper §3.2).

The IR expresses RGNN model semantics as for-each-edge / for-each-node loops
over typed graph elements, **without** dictating data layout. Constructs map
1:1 onto Table 2 of the paper:

  node/edge iterators        -> ``ForEachEdge`` / ``ForEachNode`` statements
  ``e.src``, ``e.dst``       -> ``SrcFeature`` / ``DstFeature`` accessors
  ``W[e.etype]``             -> ``Weight(name, indexed_by="etype")``
  input data ``n.feature``   -> ``NodeFeature``
  produced data ``e["att"]`` -> ``EdgeVar`` / ``NodeVar`` (layout decided later)
  GEMM-eligible ops          -> ``TypedLinear``, ``Linear``
  GEMM-ineligible ops        -> ``DotProduct``, elementwise ``Unary``/``Binary``
  manipulation               -> ``Concat``, reshape is implicit

A model is a ``Program``: an ordered list of statements. Layout choices
(vanilla vs compact materialization per edge variable) are annotations kept
*next to* the program (``Program.layouts``), never inside expressions —
that decoupling is the paper's central design point.
"""
from __future__ import annotations

import dataclasses
import enum
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class SourceLoc:
    """Where a statement was authored (filled in by the tracing frontend)."""

    file: str
    line: int
    text: str = ""

    def __str__(self) -> str:
        tail = f": {self.text}" if self.text else ""
        return f"{self.file}:{self.line}{tail}"


class Layout(enum.Enum):
    """Materialization choice for an edge-associated variable (§3.2.2)."""

    VANILLA = "vanilla"     # one row per edge (etype-sorted canonical order)
    COMPACT = "compact"     # one row per unique (src node, etype) pair


# ---------------------------------------------------------------------------
# expressions
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Expr:
    def free_inputs(self) -> Tuple["Expr", ...]:
        return ()

    def children(self) -> Tuple["Expr", ...]:
        return ()


@dataclasses.dataclass(frozen=True)
class NodeFeature(Expr):
    """Input node feature tensor [N, d]."""
    name: str = "feature"


@dataclasses.dataclass(frozen=True)
class SrcFeature(Expr):
    """``e.src.<name>`` — gather of node data by edge source."""
    name: str = "feature"


@dataclasses.dataclass(frozen=True)
class DstFeature(Expr):
    """``e.dst.<name>`` — gather of node data by edge destination."""
    name: str = "feature"


@dataclasses.dataclass(frozen=True)
class EdgeVar(Expr):
    """``e["name"]`` — produced edgewise data."""
    name: str


@dataclasses.dataclass(frozen=True)
class NodeVar(Expr):
    """``n["name"]`` — produced nodewise data."""
    name: str


@dataclasses.dataclass(frozen=True)
class Weight(Expr):
    """Model weight, optionally indexed by a type dimension.

    ``indexed_by`` in {None, "etype", "ntype_src", "ntype_dst"}; shape is the
    *per-type* shape (e.g. (d_in, d_out) for a typed linear).
    """
    name: str
    shape: Tuple[int, ...]
    indexed_by: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class TypedLinear(Expr):
    """``x @ W[type]`` — the edgewise/nodewise typed linear layer (§2.3)."""
    x: Expr
    weight: Weight

    def children(self):
        return (self.x, self.weight)


@dataclasses.dataclass(frozen=True)
class Linear(Expr):
    """Untyped linear ``x @ W`` (single relation degenerate case, §3.7)."""
    x: Expr
    weight: Weight

    def children(self):
        return (self.x, self.weight)


@dataclasses.dataclass(frozen=True)
class DotProduct(Expr):
    """Edgewise dot product -> scalar per edge (GEMM-ineligible, §3.3.1)."""
    a: Expr
    b: Expr

    def children(self):
        return (self.a, self.b)


@dataclasses.dataclass(frozen=True)
class Binary(Expr):
    op: str  # add | sub | mul | div
    a: Expr
    b: Expr

    def children(self):
        return (self.a, self.b)


@dataclasses.dataclass(frozen=True)
class Unary(Expr):
    op: str  # exp | leaky_relu | relu | sigmoid | neg | tanh
    a: Expr
    alpha: float = 0.01  # leaky_relu slope

    def children(self):
        return (self.a,)


@dataclasses.dataclass(frozen=True)
class Concat(Expr):
    parts: Tuple[Expr, ...]

    def children(self):
        return tuple(self.parts)


@dataclasses.dataclass(frozen=True)
class Scalar(Expr):
    value: float


# ---------------------------------------------------------------------------
# statements
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Stmt:
    pass


@dataclasses.dataclass(frozen=True)
class EdgeCompute(Stmt):
    """``for e in g.edges(): e[out] = expr``"""
    out: str
    expr: Expr


@dataclasses.dataclass(frozen=True)
class EdgeSoftmax(Stmt):
    """``e[out] = softmax_{edges sharing e.dst}(e[src])`` (Listing 1 lines 1-9).

    Kept as a composite statement; canonicalization may expand it into the
    exp / per-dst-sum / divide loop nest, and the traversal template re-fuses
    it (§3.2.4 loop transformation round-trips this).
    """
    out: str
    src: str


@dataclasses.dataclass(frozen=True)
class NodeAggregate(Stmt):
    """``for n: n[out] = reduce_{e in n.incoming_edges()} scale * e[msg]``.

    ``scale`` (optional edge scalar variable, e.g. attention) multiplies each
    message row; reduce is 'sum' or 'mean' (mean divides by in-degree, the
    RGCN 1/c_{v,r} normalizer folded per destination).
    """
    out: str
    msg: str
    scale: Optional[str] = None
    reduce: str = "sum"


@dataclasses.dataclass(frozen=True)
class NodeCompute(Stmt):
    """``for n in g.nodes(): n[out] = expr`` (expr over node data)."""
    out: str
    expr: Expr


# ---------------------------------------------------------------------------
# rendering (stable textual form; the basis of the structural fingerprint)
# ---------------------------------------------------------------------------
_BINOP_SYMBOL = {"add": "+", "sub": "-", "mul": "*", "div": "/"}


def render_expr(e: Expr) -> str:
    """Deterministic, fully-semantic rendering of an expression tree."""
    if isinstance(e, NodeFeature):
        return f"n.{e.name}"
    if isinstance(e, SrcFeature):
        return f"e.src.{e.name}"
    if isinstance(e, DstFeature):
        return f"e.dst.{e.name}"
    if isinstance(e, EdgeVar):
        return f"e[{e.name}]"
    if isinstance(e, NodeVar):
        return f"n[{e.name}]"
    if isinstance(e, Weight):
        dims = "x".join(str(d) for d in e.shape)
        return f"{e.name}[{e.indexed_by or 'shared'}:{dims}]"
    if isinstance(e, (TypedLinear, Linear)):
        return f"({render_expr(e.x)} @ {render_expr(e.weight)})"
    if isinstance(e, DotProduct):
        return f"dot({render_expr(e.a)}, {render_expr(e.b)})"
    if isinstance(e, Binary):
        sym = _BINOP_SYMBOL.get(e.op, e.op)
        return f"({render_expr(e.a)} {sym} {render_expr(e.b)})"
    if isinstance(e, Unary):
        if e.op == "leaky_relu":
            # repr: full float precision — the fingerprint must distinguish
            # constants closer than %g's 6 significant digits
            return f"leaky_relu({render_expr(e.a)}, {e.alpha!r})"
        return f"{e.op}({render_expr(e.a)})"
    if isinstance(e, Concat):
        return "concat(" + ", ".join(render_expr(p) for p in e.parts) + ")"
    if isinstance(e, Scalar):
        return repr(e.value)
    return repr(e)


def render_stmt(s: Stmt) -> str:
    if isinstance(s, EdgeCompute):
        return f"for e: e[{s.out}] = {render_expr(s.expr)}"
    if isinstance(s, EdgeSoftmax):
        return f"for e: e[{s.out}] = edge_softmax(e[{s.src}])"
    if isinstance(s, NodeAggregate):
        scale = f" * e[{s.scale}]" if s.scale else ""
        return (f"for n: n[{s.out}] = {s.reduce}_incoming(e[{s.msg}]"
                f"{scale})")
    if isinstance(s, NodeCompute):
        return f"for n: n[{s.out}] = {render_expr(s.expr)}"
    return repr(s)


# ---------------------------------------------------------------------------
# program
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Program:
    """An RGNN layer as inter-operator IR + decoupled layout annotations.

    ``source`` (optional, filled by the tracing frontend) maps statement
    index -> ``SourceLoc`` of the authoring model line; it is excluded from
    structural equality and from the fingerprint, so a DSL-traced program
    compares equal to its hand-built twin.
    """

    stmts: List[Stmt]
    outputs: List[str]                       # node/edge vars returned
    layouts: Dict[str, Layout] = dataclasses.field(default_factory=dict)
    name: str = "rgnn_layer"
    source: Optional[Dict[int, SourceLoc]] = dataclasses.field(
        default=None, compare=False, repr=False)

    def layout_of(self, var: str) -> Layout:
        return self.layouts.get(var, Layout.VANILLA)

    def clone(self) -> "Program":
        return Program(list(self.stmts), list(self.outputs),
                       dict(self.layouts), self.name,
                       dict(self.source) if self.source else None)

    def describe(self) -> str:
        """Stable textual rendering: every statement, the outputs, and the
        layout annotations. Two programs with identical semantics (and
        identical var names) render identically."""
        lines = [f"Program<{self.name}>"]
        lines += ["  " + render_stmt(s) for s in self.stmts]
        lines.append("  outputs: " + ", ".join(self.outputs))
        if self.layouts:
            lines.append("  layouts: " + ", ".join(
                f"{k}={v.value}" for k, v in sorted(self.layouts.items())))
        return "\n".join(lines)

    def fingerprint(self) -> str:
        """Structural-identity hash (hex). DSL-traced and hand-built
        programs with the same statements/outputs/layouts/name fingerprint
        identically; executor/tuning caches may key on it."""
        return hashlib.sha256(self.describe().encode()).hexdigest()[:16]

    def weights(self) -> Dict[str, Weight]:
        out: Dict[str, Weight] = {}

        def visit(e: Expr):
            if isinstance(e, Weight):
                out[e.name] = e
            for c in e.children():
                visit(c)

        for s in self.stmts:
            if isinstance(s, (EdgeCompute, NodeCompute)):
                visit(s.expr)
        return out


# ---------------------------------------------------------------------------
# expression analysis helpers used by the passes
# ---------------------------------------------------------------------------
def expr_deps(e: Expr) -> set:
    """Set of dependency tags: 'src', 'dst', 'etype', 'ntype', edge/node vars."""
    deps: set = set()

    def visit(x: Expr):
        if isinstance(x, SrcFeature):
            deps.add("src")
        elif isinstance(x, DstFeature):
            deps.add("dst")
        elif isinstance(x, EdgeVar):
            deps.add(("evar", x.name))
        elif isinstance(x, NodeVar):
            deps.add(("nvar", x.name))
        elif isinstance(x, Weight) and x.indexed_by == "etype":
            deps.add("etype")
        elif isinstance(x, Weight) and x.indexed_by in ("ntype_src", "ntype_dst"):
            deps.add("ntype")
            deps.add("src" if x.indexed_by == "ntype_src" else "dst")
        for c in x.children():
            visit(c)

    visit(e)
    return deps


def compactable(e: Expr, compact_vars: set) -> bool:
    """True if an edgewise expression depends only on (src, etype) — the
    compact-materialization applicability condition (§3.2.2). Reading another
    edge var is fine iff that var is itself compact."""
    deps = expr_deps(e)
    if "dst" in deps:
        return False
    for d in deps:
        if isinstance(d, tuple) and d[0] == "evar" and d[1] not in compact_vars:
            return False
    return True
