"""HectorModule — the public compile() entry point.

Usage (the 51-lines-of-model-code experience of §4.1):

    prog = rgat_program(in_dim=64, out_dim=64)       # inter-operator IR
    mod = HectorModule(prog, graph, reorder=True, compact=True)
    params = mod.init(jax.random.key(0))
    out = mod.apply(params, {"feature": x})          # jitted generated code
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import codegen
from repro.core.graph import HeteroGraph
from repro.core.ir import inter_op as I
from repro.core.ir.passes import lower_program


class HectorModule:
    def __init__(
        self,
        program: I.Program,
        graph: HeteroGraph,
        *,
        reorder: bool = True,
        compact: bool = True,
        backend: str = "xla",
        tile: int = 128,
        node_block: int = 128,
        jit: bool = True,
    ):
        self.program = program
        self.graph = graph
        self.plan = lower_program(program, reorder=reorder, compact=compact)
        self.gt = graph.to_tensors()
        self.layouts = codegen.build_kernel_layouts(
            graph, tile=tile, node_block=node_block
        )
        self.backend = backend
        self._apply = functools.partial(
            codegen.execute_plan,
            self.plan,
            gt=self.gt,
            kl=self.layouts,
            backend=self.backend,
        )
        if jit:
            self._apply_jit = jax.jit(
                lambda params, feats: codegen.execute_plan(
                    self.plan, params, self.gt, feats, self.layouts,
                    self.backend,
                )
            )
        else:
            self._apply_jit = None

    # ------------------------------------------------------------------
    def init(self, key: jax.Array, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
        return codegen.init_params(self.plan, self.gt, key, dtype)

    def apply(self, params, feats: Dict[str, jnp.ndarray]):
        if self._apply_jit is not None:
            return self._apply_jit(params, feats)
        return codegen.execute_plan(
            self.plan, params, self.gt, feats, self.layouts, self.backend
        )

    def describe(self) -> str:
        return self.plan.describe()

    @property
    def entity_compaction_ratio(self) -> float:
        return self.graph.entity_compaction_ratio
