"""HectorModule / HectorStack — the single-layer / multi-layer compilation
units underneath the public ``hector.compile()`` facade
(``repro.frontend``).

Direct usage (the low-level per-layer API; most callers should go through
``hector.compile`` instead):

    prog = rgat_program(in_dim=64, out_dim=64)       # traced inter-op IR
    mod = HectorModule(prog, graph, reorder=True, compact=True)
    params = mod.init(jax.random.key(0))
    out = mod.apply(params, {"feature": x})          # jitted generated code
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import codegen, executor
from repro.core.graph import HeteroGraph
from repro.core.ir import inter_op as I
from repro.core.ir.passes import lower_program


class HectorModule:
    def __init__(
        self,
        program: I.Program,
        graph: HeteroGraph,
        *,
        reorder: bool = True,
        compact: bool = True,
        compact_vars=None,
        backend: str = "xla",
        tile: int = 128,
        node_block: int = 128,
        jit: bool = True,
        gt=None,
        layouts: Optional[codegen.KernelLayouts] = None,
        decisions=None,
    ):
        self.program = program
        self.graph = graph
        # compact_vars (per-var materialization) and decisions (per-op
        # variants) come from the autotuner; both default to the paper's
        # static policies when absent
        self.plan = lower_program(program, reorder=reorder, compact=compact,
                                  compact_vars=compact_vars)
        # gt/layouts may be shared across modules over the same graph
        # (HectorStack builds them once for all layers)
        self.gt = graph.to_tensors() if gt is None else gt
        self.layouts = layouts if layouts is not None else \
            codegen.build_kernel_layouts(graph, tile=tile,
                                         node_block=node_block)
        self.backend = backend
        self.decisions = decisions
        # whole-plan compiled executor: graph tensors and layouts flow in as
        # pytree arguments, fronted by an explicit compile cache
        self.executor = executor.PlanExecutor(
            self.plan, backend=backend, decisions=decisions) if jit else None

    # ------------------------------------------------------------------
    def init(self, key: jax.Array, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
        return codegen.init_params(self.plan, self.gt, key, dtype)

    def apply(self, params, feats: Dict[str, jnp.ndarray]):
        if self.executor is not None:
            return self.executor(params, self.gt, self.layouts, feats)
        return codegen.execute_plan(
            self.plan, params, self.gt, feats, self.layouts, self.backend,
            self.decisions
        )

    def describe(self) -> str:
        return self.plan.describe()

    @property
    def entity_compaction_ratio(self) -> float:
        return self.graph.entity_compaction_ratio


class HectorStack:
    """A multi-layer RGNN: one Hector program per layer, with an elementwise
    activation between layers.

    Two execution paths share the same lowered plans and parameters:

    * ``apply(params, feats)``        — full-graph forward (all nodes);
    * ``apply_blocks(params, mb, x)`` — sampled mini-batch forward over a
      prefetched ``repro.sampling.MiniBatch``: one layer per hop, each over
      its block's own graph tensors/kernel layouts, returning the rows for
      the requested seeds (in request order, duplicates included).

    With full-neighborhood fanout the two paths agree within fp32 tolerance
    on the seed rows — the invariant the sampling tests pin down.
    """

    def __init__(
        self,
        programs: Sequence[I.Program],
        graph: HeteroGraph,
        *,
        reorder: bool = True,
        compact: bool = True,
        compact_vars: Optional[Sequence] = None,   # per-layer COMPACT sets
        backend: str = "xla",
        tile: int = 128,
        node_block: int = 128,
        activation: str = "relu",
        jit: bool = True,
        decisions=None,
    ):
        if not programs:
            raise ValueError("need at least one layer program")
        if compact_vars is not None and len(compact_vars) != len(programs):
            raise ValueError("need one compact-var set per layer (None to "
                             "keep a layer's default)")
        # full-graph tensors/layouts are identical across layers: build once
        gt = graph.to_tensors()
        layouts = codegen.build_kernel_layouts(graph, tile=tile,
                                               node_block=node_block)
        self.layers = [
            HectorModule(p, graph, reorder=reorder, compact=compact,
                         compact_vars=(None if compact_vars is None
                                       else compact_vars[i]),
                         backend=backend, tile=tile, node_block=node_block,
                         jit=jit, gt=gt, layouts=layouts,
                         decisions=decisions)
            for i, p in enumerate(programs)
        ]
        self.activation = activation
        self.backend = backend
        self.jit = jit
        self.decisions = decisions
        self._act = codegen._ACTIVATIONS[activation]
        # whole-plan compiled executor over the entire block sequence (all
        # hops in one jitted callable, fronted by a compile cache keyed on
        # the bucketed layout shapes) — the serving hot path
        self.block_executor = executor.BlockExecutor(
            self.plans, backend=backend, activation=activation,
            decisions=decisions)

    @property
    def num_layers(self) -> int:
        return len(self.layers)

    @property
    def plans(self):
        return [l.plan for l in self.layers]

    # ------------------------------------------------------------------
    def init(self, key: jax.Array, dtype=jnp.float32) -> List[Dict[str, jnp.ndarray]]:
        keys = jax.random.split(key, self.num_layers)
        return [l.init(k, dtype) for l, k in zip(self.layers, keys)]

    def apply(self, params: Sequence[Dict[str, jnp.ndarray]],
              feats: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """Full-graph forward; returns the last layer's primary output."""
        cur = dict(feats)
        h = None
        for i, (layer, p) in enumerate(zip(self.layers, params)):
            out = layer.apply(p, cur)
            h = out[layer.plan.outputs[0]]
            if i < self.num_layers - 1:
                cur = {"feature": self._act(h)}
        return h

    def apply_blocks(self, params: Sequence[Dict[str, jnp.ndarray]],
                     mb, global_feats: Optional[jnp.ndarray] = None,
                     compiled: Optional[bool] = None,
                     feats: Optional[Dict[str, jnp.ndarray]] = None
                     ) -> jnp.ndarray:
        """Sampled forward over a ``MiniBatch``; returns [len(seeds), out].

        ``compiled=True`` runs the whole block sequence through the jitted
        ``BlockExecutor`` (cache-hit on repeated bucketed shapes);
        ``compiled=False`` is the op-by-op eager loop for debugging. The
        default follows the stack's ``jit`` flag.

        Input features come from ``feats`` (an explicit pre-gathered
        pytree), else ``mb.feats`` (attached by a feature-store-wired
        loader), else an on-device gather from ``global_feats``.
        """
        if compiled is None:
            compiled = self.jit
        if mb.num_hops != self.num_layers:
            raise ValueError(
                f"minibatch has {mb.num_hops} hops but the stack has "
                f"{self.num_layers} layers"
            )
        if compiled:
            return self.block_executor.run_minibatch(
                list(params), mb, global_feats, feats=feats)
        if feats is None:
            feats = getattr(mb, "feats", None)
        if feats is None:
            feats = {"feature": global_feats[mb.input_ids]}
        return codegen.execute_block_sequence(
            self.plans, list(params), mb.tensors, mb.layouts, mb.dst_locals,
            mb.seed_perm, feats, backend=self.backend,
            activation=self.activation, decisions=self.decisions,
        )
