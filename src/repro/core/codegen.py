"""Hector code generator (paper §3.6), TPU/JAX adaptation.

GPU Hector emits CUDA kernels + host functions from intra-operator IR specs.
The JAX equivalent of "emitting code" is building **closed jitted callables**:
each ``GemmSpec`` instantiates the segment-MM kernel (Pallas) or its XLA
formulation with the access schemes baked in; each ``TraversalSpec`` executes
its fused statement region, pattern-matching the canonical fused
edge-softmax(+aggregate) region onto the fused traversal kernel. Fallbacks
run as plain jnp ops (the "PyTorch fallback" of §3.2.5).

Auto-differentiation: the paper pairs hand-written backward kernels via
``autograd.Function`` (§3.5); here every kernel op carries a ``custom_vjp``
whose backward is itself template-derived (outer-product GEMM instances for
dW, traversal instances for feature grads) — see kernels/ops.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import jax.tree_util as jtu
import numpy as np

from repro import compat
from repro.core.graph import GraphTensors, HeteroGraph
from repro.core.ir import inter_op as I
from repro.core.ir import intra_op as O
from repro.kernels import layout as L
from repro.kernels import ops as K
from repro.tune import device as tunedev
from repro.tune import space as tspace


@dataclasses.dataclass(frozen=True, eq=False)
class KernelLayouts:
    """Per-graph tile-aligned layouts for the generated kernels (host-built).

    Besides the segment/CSR layouts this carries the *padded gather-index
    layouts* (§3.3 access schemes composed with the tile padding maps), so
    the Pallas kernels can gather their input rows in-kernel, and the
    precomputed per-destination in-degree used by mean aggregation.
    Registered as a pytree (metadata static) so whole plans can be jitted
    with the layouts as run-time arguments.
    """

    edge_seg: K.PaddedSegmentsDev      # etype segments over canonical edges
    unique_seg: K.PaddedSegmentsDev    # etype segments over unique (src,etype)
    node_seg: K.PaddedSegmentsDev      # ntype segments over nodes
    blocked: K.BlockedCSRDev           # dst-sorted blocked CSR
    edge_src_rows: jnp.ndarray         # [Rp_e] padded slot -> src node, or -1
    edge_dst_rows: jnp.ndarray         # [Rp_e] padded slot -> dst node, or -1
    unique_src_rows: jnp.ndarray       # [Rp_u] padded slot -> src node, or -1
    dst_deg: jnp.ndarray               # [N] float32 per-destination in-degree


_KL_FIELDS = ("edge_seg", "unique_seg", "node_seg", "blocked",
              "edge_src_rows", "edge_dst_rows", "unique_src_rows", "dst_deg")

jtu.register_pytree_node(
    KernelLayouts,
    lambda kl: (tuple(getattr(kl, f) for f in _KL_FIELDS), None),
    lambda aux, ch: KernelLayouts(*ch),
)


def build_kernel_layouts(
    hg: HeteroGraph, tile: int = 128, node_block: int = 128,
    bucket: bool = False, row_floors=None,
) -> KernelLayouts:
    """Build the per-graph layouts; with ``bucket=True`` every layout is
    grown to power-of-two row/edge-slot counts (pure padding), so repeated
    compilation caches hit across sampled blocks of different sizes.

    The segment-row buckets depend on how edges distribute across
    segments, not just the graph's padded totals, so blocks sharing one
    (node, edge, unique) bucket combination can still disagree here.
    ``row_floors`` (a ``bucketing.LayoutRowFloors``) clamps each field's
    bucket to a grow-only floor shared across blocks, pinning the layout
    shapes the way ``pad_block_graph`` targets pin the graph shapes."""
    edge_ps = L.pad_segments(hg.etype_ptr, tile)
    unique_ps = L.pad_segments(hg.unique_etype_ptr, tile)
    node_ps = L.pad_segments(hg.ntype_ptr, tile)
    bc = L.block_csr(hg.dst_ptr, edge_tile=tile, node_block=node_block)
    if bucket:
        if tile & (tile - 1):
            raise ValueError("bucketed layouts need a power-of-two tile")

        def bucket_rows(name: str, rows: int) -> int:
            t = max(tile, L.pow2ceil(rows))
            if row_floors is not None:
                t = row_floors.raise_to(name, t)
            return t
        edge_ps = L.pad_segments_rows(
            edge_ps, bucket_rows("edge", edge_ps.padded_rows))
        unique_ps = L.pad_segments_rows(
            unique_ps, bucket_rows("unique", unique_ps.padded_rows))
        node_ps = L.pad_segments_rows(
            node_ps, bucket_rows("node", node_ps.padded_rows))
        bc = L.pad_blocked_csr(bc, bucket_rows("csr", bc.padded_edges))
    return KernelLayouts(
        edge_seg=K.padded_segments_dev(edge_ps),
        unique_seg=K.padded_segments_dev(unique_ps),
        node_seg=K.padded_segments_dev(node_ps),
        blocked=K.blocked_csr_dev(bc, hg.perm_dst, hg.edge_to_unique),
        edge_src_rows=jnp.asarray(L.compose_gather_rows(edge_ps, hg.src)),
        edge_dst_rows=jnp.asarray(L.compose_gather_rows(edge_ps, hg.dst)),
        unique_src_rows=jnp.asarray(
            L.compose_gather_rows(unique_ps, hg.unique_src)),
        dst_deg=jnp.asarray(np.diff(hg.dst_ptr).astype(np.float32)),
    )


# ---------------------------------------------------------------------------
# parameter initialization from the plan's weight table
# ---------------------------------------------------------------------------
def init_params(
    plan: O.Plan, gt: GraphTensors, key: jax.Array, dtype=jnp.float32
) -> Dict[str, jnp.ndarray]:
    params: Dict[str, jnp.ndarray] = {}
    names = sorted(n for n in plan.weights if not n.startswith("_wprod"))
    keys = jax.random.split(key, max(1, len(names)))
    for k, name in zip(keys, names):
        w = plan.weights[name]
        if w.indexed_by == "etype":
            lead = (gt.num_etypes,)
        elif w.indexed_by in ("ntype", "ntype_src", "ntype_dst"):
            lead = (gt.num_ntypes,)
        else:
            lead = ()
        shape = lead + tuple(w.shape)
        fan_in = w.shape[0] if len(w.shape) >= 1 else 1
        scale = 1.0 / math.sqrt(max(1, fan_in))
        params[name] = (jax.random.normal(k, shape) * scale).astype(dtype)
    return params


# ---------------------------------------------------------------------------
# the generated forward function
# ---------------------------------------------------------------------------
_SOFTMAX_TAIL = ("segment_max", "gather_dst_var", "elementwise", "elementwise",
                 "segment_sum", "gather_dst_var", "elementwise")


class _Env:
    """Execution environment: name -> array, with layout-aware edge reads."""

    def __init__(self, plan: O.Plan, gt: GraphTensors, params, feats):
        self.plan = plan
        self.gt = gt
        self.vals: Dict[str, jnp.ndarray] = {}
        for name, v in feats.items():
            self.vals["node:" + name] = v
        self.params = dict(params)

    def get(self, name: str) -> jnp.ndarray:
        if name.startswith("scalar:"):
            return jnp.float32(float(name.split(":", 1)[1]))
        if name in self.vals:
            return self.vals[name]
        if name.startswith("node:") and name[5:] in self.vals:
            return self.vals[name[5:]]
        raise KeyError(f"undefined IR value {name!r}; have {list(self.vals)}")

    def get_edge_vanilla(self, name: str) -> jnp.ndarray:
        """Read an edge var in canonical per-edge order, resolving compact
        layout through the edge_to_unique indirection."""
        v = self.get(name)
        if self.plan.layouts.get(name) == I.Layout.COMPACT:
            return v[self.gt.edge_to_unique]
        return v

    def set(self, name: str, v: jnp.ndarray):
        self.vals[name] = v


def _elementwise(op: str, args, alpha: float = 0.01):
    a = args[0]
    if len(args) == 1:
        if op == "exp":
            return jnp.exp(a)
        if op == "leaky_relu":
            return jnp.where(a > 0, a, alpha * a)
        if op == "relu":
            return jnp.maximum(a, 0)
        if op == "sigmoid":
            return jax.nn.sigmoid(a)
        if op == "tanh":
            return jnp.tanh(a)
        if op == "neg":
            return -a
        raise ValueError(op)
    b = args[1]
    if a.ndim == 2 and b.ndim == 1:
        b = b[:, None]
    elif a.ndim == 1 and b.ndim == 2:
        a = a[:, None]
    if op == "add":
        return a + b
    if op == "sub":
        return a - b
    if op == "mul":
        return a * b
    if op == "div":
        return a / b
    raise ValueError(op)


def execute_plan(
    plan: O.Plan,
    params: Dict[str, jnp.ndarray],
    gt: GraphTensors,
    feats: Dict[str, jnp.ndarray],
    kl: KernelLayouts,
    backend: str = "xla",
    decisions=None,
) -> Dict[str, jnp.ndarray]:
    """Run the lowered layer. Returns {output name: array}.

    ``decisions`` is an optional ``tune.TuningDecisions`` table; op
    instances found in it dispatch on the recorded variant (backend, tile
    shape, gather fusion) instead of the hardcoded defaults.
    """
    env = _Env(plan, gt, params, feats)
    derived: Dict[str, jnp.ndarray] = {}
    for op in plan.ops:
        execute_op(op, env, derived, gt, kl, backend, decisions)
    return {name: env.get(name) for name in plan.outputs}


def execute_op(op, env: _Env, derived: Dict[str, jnp.ndarray],
               gt: GraphTensors, kl: KernelLayouts, backend: str = "xla",
               decisions=None) -> None:
    """Execute ONE lowered op spec against the environment — the loop body
    of ``execute_plan``, factored out so the obs profiler can advance a
    plan op by op and time each instance individually.

    ``derived`` carries hoisted weight products (``WeightProductSpec``
    outputs) that later GEMMs resolve before the parameter table.
    """
    if isinstance(op, O.WeightProductSpec):
        wm, wv = env.params[op.w_matrix], env.params[op.w_vector]
        # (x W_r) · w_r == x (W_r w_r^T): hoisted weight-weight BMM
        derived[op.out] = jnp.einsum("rdf,rf->rd", wm, wv)[..., None]
    elif isinstance(op, O.GemmSpec):
        _exec_gemm(op, env,
                   lambda name: derived.get(name, env.params.get(name)),
                   gt, kl, backend, decisions)
    elif isinstance(op, O.TraversalSpec):
        _exec_traversal(op, env, gt, kl, backend, decisions)
    elif isinstance(op, O.FallbackSpec):
        raise NotImplementedError(
            f"fallback op {op.stmt} reached the executor; add a jnp "
            f"lowering for it"
        )


# ---------------------------------------------------------------------------
# block-sequence execution (sampled mini-batch path)
# ---------------------------------------------------------------------------
_ACTIVATIONS = {
    "relu": lambda x: jnp.maximum(x, 0),
    "gelu": jax.nn.gelu,
    "tanh": jnp.tanh,
    "none": lambda x: x,
    None: lambda x: x,
}


def execute_block_sequence(
    plans,                  # List[O.Plan], one lowered layer per hop
    params,                 # List[Dict[str, jnp.ndarray]] per layer
    gts,                    # List[GraphTensors] per block
    kls,                    # List[KernelLayouts] per block
    dst_locals,             # List[jnp.ndarray]: out-frontier rows per block
    seed_perm: jnp.ndarray,  # final-frontier row of each requested seed
    feats: Dict[str, jnp.ndarray],  # features for the first block's node set
    backend: str = "xla",
    activation: str = "relu",
    decisions=None,
) -> jnp.ndarray:
    """Run one lowered layer per sampled hop, narrowing to each hop's output
    frontier, and gather the requested seed rows from the last hop.

    The mini-batch analogue of ``execute_plan``: every hop executes the same
    generated code over its block's own ``GraphTensors``/``KernelLayouts``
    (which are just smaller instances of the full-graph products), and the
    host-precomputed ``dst_locals`` maps align hop l's outputs with hop
    l+1's node set.
    """
    if not (len(plans) == len(params) == len(gts) == len(kls)
            == len(dst_locals)):
        raise ValueError("plans/params/blocks length mismatch")
    act = _ACTIVATIONS[activation]
    cur = dict(feats)
    h = None
    last = len(plans) - 1
    for i, (plan, p, gt, kl) in enumerate(zip(plans, params, gts, kls)):
        out = execute_plan(plan, p, gt, cur, kl, backend, decisions)
        h = out[plan.outputs[0]][dst_locals[i]]
        if i < last:
            cur = {"feature": act(h)}
    return h[seed_perm]


# gather schemes whose row lists have a precomposed padded gather-index
# layout in KernelLayouts (-> eligible for the in-kernel gather kernels)
_FUSABLE_GATHERS = (O.GatherScheme.BY_EDGE_SRC, O.GatherScheme.BY_EDGE_DST,
                    O.GatherScheme.BY_UNIQUE_SRC)


def _fits_vmem(arr, *index_arrays) -> bool:
    """Default gather-fusion heuristic: the ungathered source block PLUS the
    scalar-prefetched gather/slot-map index arrays must all stay resident in
    VMEM, inside the budget derived from the device's actual VMEM size
    (``tune/device.py``; overridable via env)."""
    total = arr.size * arr.dtype.itemsize
    for ix in index_arrays:
        if ix is not None:
            total += ix.size * ix.dtype.itemsize
    return total <= tunedev.fused_gather_budget_bytes()


def _gemm_decision(decisions, op, lay, x_src, w, has_scale):
    if decisions is None or lay is None:
        return None
    key = tspace.gemm_key(op, lay, int(x_src.shape[0]), int(w.shape[-2]),
                          int(w.shape[-1]), has_scale, x_src.dtype)
    return decisions.lookup(key)


def _trav_decision(decisions, kind, msg, compact_msg, kl):
    if decisions is None:
        return None
    key = tspace.trav_key(kind, int(msg.shape[-1]), compact_msg, kl.blocked,
                          msg.dtype)
    return decisions.lookup(key)


def _exec_gemm(op: O.GemmSpec, env: _Env, weight, gt: GraphTensors,
               kl: KernelLayouts, backend: str, decisions=None):
    w = weight(op.weight)

    scale = None
    if op.per_row_scale is not None:
        scale = env.get_edge_vanilla(op.per_row_scale)
        if scale.ndim == 2:
            scale = scale[:, 0]

    # resolve the access scheme: layout, padded gather map, gather list
    if op.gather == O.GatherScheme.BY_EDGE_SRC:
        lay, gmap, gidx = kl.edge_seg, kl.edge_src_rows, gt.src
        x_src = env.get(op.x_source)
    elif op.gather == O.GatherScheme.BY_EDGE_DST:
        lay, gmap, gidx = kl.edge_seg, kl.edge_dst_rows, gt.dst
        x_src = env.get(op.x_source)
    elif op.gather == O.GatherScheme.BY_UNIQUE_SRC:
        lay, gmap, gidx = kl.unique_seg, kl.unique_src_rows, gt.unique_src
        x_src = env.get(op.x_source)
    elif op.gather == O.GatherScheme.BY_NODE:
        lay, gmap, gidx = kl.node_seg, None, None
        x_src = env.get(op.x_source)
    else:  # IDENTITY: var already in segment-sorted order
        x_src = env.get(op.x_source.split(":", 1)[1]
                        if op.x_source.startswith("edge:") else op.x_source)
        lay = {
            "etype_ptr": kl.edge_seg,
            "unique_etype_ptr": kl.unique_seg,
            "ntype_ptr": kl.node_seg,
        }.get(op.seg_ptr)
        gmap = gidx = None

    typed = op.type_index != O.TypeIndex.NONE
    dec = _gemm_decision(decisions, op, lay, x_src, w, scale is not None) \
        if typed else None
    backend_eff = backend
    tile_rows = tile_n = None
    if dec is not None:
        if dec.backend != tspace.DEFAULT:
            backend_eff = dec.backend
        tile_rows, tile_n = dec.tile_rows, dec.tile_n

    # Pallas backends with a typed GEMM: fold the access-scheme gather into
    # the kernel via the padded gather-index layout — the [rows, k] input
    # copy is never materialized outside the kernel (paper §3.3). The tuned
    # decision overrides the VMEM-budget heuristic either way.
    if (backend_eff != "xla" and typed and gmap is not None
            and op.gather in _FUSABLE_GATHERS):
        fuse = (dec.fuse_gather
                if dec is not None and dec.fuse_gather is not None
                else _fits_vmem(x_src, gmap))
        if fuse:
            y = K.segment_mm_gather(x_src, w, lay, gmap, row_scale=scale,
                                    backend=backend_eff,
                                    tile_n=tile_n or 128,
                                    tile_rows=tile_rows)
            out = y[:, 0] if (op.out_cols == 1 and y.shape[-1] == 1) else y
            env.set(op.out, out)
            return

    # materialized gather (XLA fuses the gather into the consumer)
    x = x_src if gidx is None else x_src[gidx]
    if not typed:
        y = x @ w
        if scale is not None:
            y = y * scale[:, None]
    else:
        y = K.segment_mm(x, w, lay, row_scale=scale, backend=backend_eff,
                         tile_n=tile_n or 128, tile_rows=tile_rows)
    out = y[:, 0] if (op.out_cols == 1 and y.shape[-1] == 1) else y
    env.set(op.out, out)


def _edge_msg(env: _Env, gt: GraphTensors, kl: KernelLayouts, name: str):
    """Resolve a feature-wide edge var in its *storage* order for the
    traversal kernels: COMPACT vars stay in the unique-pair table and carry
    the precomposed slot map, so the per-edge expansion happens in-kernel
    instead of materializing an [E, d] copy here."""
    v = env.get(name)
    if env.plan.layouts.get(name) == I.Layout.COMPACT:
        return v, gt.edge_to_unique, kl.blocked.edge_map_unique
    return v, None, kl.blocked.edge_map


def _exec_traversal(op: O.TraversalSpec, env: _Env, gt: GraphTensors,
                    kl: KernelLayouts, backend: str, decisions=None):
    """Execute a fused traversal region, fusing the canonical softmax(+agg)
    pattern onto the Pallas traversal kernel when present."""
    stmts = op.stmts
    i = 0
    while i < len(stmts):
        # peephole: expanded softmax (7 stmts) [+ segment_sum scaled by it]
        if (
            i + len(_SOFTMAX_TAIL) <= len(stmts)
            and tuple(s.kind for s in stmts[i : i + 7]) == _SOFTMAX_TAIL
        ):
            score_name = stmts[i].ins[0]
            att_name = stmts[i + 6].out
            scores = env.get_edge_vanilla(score_name)
            if scores.ndim == 2:
                scores = scores[:, 0]
            nxt = stmts[i + 7] if i + 7 < len(stmts) else None
            if (
                nxt is not None
                and nxt.kind == "segment_sum"
                and nxt.scale == att_name
            ):
                msg, msg_rows, slot_map = _edge_msg(env, gt, kl, nxt.ins[0])
                dec = _trav_decision(decisions, "softmax_agg", msg,
                                     msg_rows is not None, kl)
                backend_eff = backend
                if dec is not None and dec.backend != tspace.DEFAULT:
                    backend_eff = dec.backend
                if backend_eff != "xla":
                    # fully fused softmax+aggregate traversal kernel
                    fuse = (dec.fuse_gather
                            if dec is not None and dec.fuse_gather is not None
                            else _fits_vmem(msg, slot_map))
                    out = K.edge_softmax_agg(
                        scores, msg, gt.dst, gt.num_nodes,
                        bc=kl.blocked, backend=backend_eff,
                        msg_rows=msg_rows, msg_slot_map=slot_map,
                        fuse_gather=fuse,
                    )
                    env.set(nxt.out, out)
                    env.set(att_name,
                            K.edge_softmax(scores, gt.dst, gt.num_nodes))
                    i += 8
                    continue
            env.set(att_name, K.edge_softmax(scores, gt.dst, gt.num_nodes))
            i += 7
            continue

        s = stmts[i]
        if s.kind == "elementwise":
            args = [env.get_edge_vanilla(a) if not a.startswith(("node:", "scalar:"))
                    else env.get(a) for a in s.ins]
            env.set(s.out, _elementwise(s.op, args, s.alpha))
        elif s.kind == "rowdot":
            a = env.get_edge_vanilla(s.ins[0])
            b = env.get_edge_vanilla(s.ins[1])
            env.set(s.out, jnp.sum(a * b, axis=-1))
        elif s.kind == "concat":
            env.set(s.out, jnp.concatenate(
                [env.get_edge_vanilla(a) for a in s.ins], axis=-1))
        elif s.kind == "gather_src":
            env.set(s.out, env.get(s.ins[0])[gt.src])
        elif s.kind == "gather_dst":
            env.set(s.out, env.get(s.ins[0])[gt.dst])
        elif s.kind == "gather_dst_var":
            env.set(s.out, env.get(s.ins[0])[gt.dst])
        elif s.kind == "gather_unique":
            env.set(s.out, env.get(s.ins[0])[gt.edge_to_unique])
        elif s.kind == "gather_etype_weight":
            env.set(s.out, env.params[s.ins[0]][gt.etype])
        elif s.kind == "segment_max":
            x = env.get_edge_vanilla(s.ins[0])
            mx = compat.segment_max(x, gt.dst, gt.num_nodes)
            env.set(s.out, jnp.where(jnp.isfinite(mx), mx, 0.0))
        elif s.kind == "segment_sum":
            msg, msg_rows, slot_map = _edge_msg(env, gt, kl, s.ins[0])
            dec = _trav_decision(decisions, "weighted_agg", msg,
                                 msg_rows is not None, kl)
            backend_eff = backend
            if dec is not None and dec.backend != tspace.DEFAULT:
                backend_eff = dec.backend
            fuse = (dec.fuse_gather
                    if dec is not None and dec.fuse_gather is not None
                    else _fits_vmem(msg, slot_map))
            scale = None
            if s.scale is not None:
                scale = env.get_edge_vanilla(s.scale)
                if scale.ndim == 2:
                    scale = scale[:, 0]
            out = K.weighted_agg(scale, msg, gt.dst, gt.num_nodes,
                                 bc=kl.blocked, backend=backend_eff,
                                 msg_rows=msg_rows, msg_slot_map=slot_map,
                                 fuse_gather=fuse)
            if s.op == "mean":
                deg = kl.dst_deg.astype(out.dtype)
                out = out / jnp.maximum(deg, 1.0)[:, None]
            env.set(s.out, out)
        else:
            raise NotImplementedError(f"traversal stmt {s.kind}")
        i += 1
