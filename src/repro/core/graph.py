"""Heterogeneous graph substrate for Hector.

The paper's layout story (§3.2.2) needs, per graph, a small set of host-side
preprocessing products:

  * edges presorted by edge type  -> ``etype_ptr`` segment offsets (enables
    segment-MM typed linear layers, exactly as the paper presorts);
  * edges sorted by destination   -> CSR ``dst_ptr`` (enables deterministic
    segment aggregation on TPU, replacing GPU atomics);
  * the compact-materialization map: unique (source node, edge type) pairs,
    the per-edge index into the unique table, and the unique table's own
    etype segmentation (``unique_etype_ptr``) — Fig. 7(b) of the paper.

Everything here is NumPy (host preprocessing); ``GraphTensors`` is the device
pytree handed to generated code.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax.numpy as jnp


def _segment_ptr(sorted_types: np.ndarray, num_types: int) -> np.ndarray:
    """Offsets of each type segment in a type-sorted array (len num_types+1)."""
    counts = np.bincount(sorted_types, minlength=num_types)
    ptr = np.zeros(num_types + 1, dtype=np.int32)
    np.cumsum(counts, out=ptr[1:])
    return ptr


@dataclasses.dataclass
class HeteroGraph:
    """Host-side heterograph with all Hector preprocessing applied.

    Edge arrays are stored in *etype-sorted* order (the canonical layout for
    GEMM-template instances). ``perm_dst`` re-sorts edges by destination for
    traversal-template aggregation.
    """

    num_nodes: int
    num_ntypes: int
    num_etypes: int
    # canonical (etype-sorted) edge arrays
    src: np.ndarray          # [E] int32
    dst: np.ndarray          # [E] int32
    etype: np.ndarray        # [E] int32, non-decreasing
    etype_ptr: np.ndarray    # [R+1] int32 segment offsets
    node_type: np.ndarray    # [N] int32, non-decreasing (nodes presorted)
    ntype_ptr: np.ndarray    # [T+1] int32
    # destination-sorted view (for aggregation)
    perm_dst: np.ndarray     # [E] int32: canonical index of i-th dst-sorted edge
    dst_sorted: np.ndarray   # [E] int32 non-decreasing
    dst_ptr: np.ndarray      # [N+1] int32 CSR by destination
    # compact materialization map (Fig. 7b)
    unique_src: np.ndarray        # [U] int32 gather list: source node of unique pair
    unique_etype: np.ndarray      # [U] int32 non-decreasing
    unique_etype_ptr: np.ndarray  # [R+1] int32
    edge_to_unique: np.ndarray    # [E] int32: canonical edge -> unique row

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_unique(self) -> int:
        return int(self.unique_src.shape[0])

    @property
    def entity_compaction_ratio(self) -> float:
        """#unique (src, etype) pairs / #edges — the paper's Fig. 10 metric."""
        return self.num_unique / max(1, self.num_edges)

    # ------------------------------------------------------------------
    @staticmethod
    def from_edges(
        src: np.ndarray,
        dst: np.ndarray,
        etype: np.ndarray,
        num_nodes: int,
        num_etypes: int,
        node_type: Optional[np.ndarray] = None,
        num_ntypes: int = 1,
    ) -> "HeteroGraph":
        src = np.asarray(src, dtype=np.int32)
        dst = np.asarray(dst, dtype=np.int32)
        etype = np.asarray(etype, dtype=np.int32)
        if node_type is None:
            node_type = np.zeros(num_nodes, dtype=np.int32)
        node_type = np.asarray(node_type, dtype=np.int32)
        if not np.all(np.diff(node_type) >= 0):
            raise ValueError("nodes must be presorted by type (paper §4.1)")

        # canonical order: sort edges by etype (stable keeps locality)
        order = np.argsort(etype, kind="stable").astype(np.int32)
        src, dst, etype = src[order], dst[order], etype[order]
        etype_ptr = _segment_ptr(etype, num_etypes)
        ntype_ptr = _segment_ptr(node_type, num_ntypes)

        # destination-sorted view
        perm_dst = np.argsort(dst, kind="stable").astype(np.int32)
        dst_sorted = dst[perm_dst]
        dst_ptr = np.zeros(num_nodes + 1, dtype=np.int32)
        np.cumsum(np.bincount(dst_sorted, minlength=num_nodes), out=dst_ptr[1:])

        # compact materialization: unique (src, etype), etype-major keyed so
        # the unique table is itself etype-sorted (=> segment MM applies).
        key = etype.astype(np.int64) * np.int64(num_nodes) + src.astype(np.int64)
        uniq_key, edge_to_unique = np.unique(key, return_inverse=True)
        unique_etype = (uniq_key // num_nodes).astype(np.int32)
        unique_src = (uniq_key % num_nodes).astype(np.int32)
        unique_etype_ptr = _segment_ptr(unique_etype, num_etypes)

        return HeteroGraph(
            num_nodes=num_nodes,
            num_ntypes=num_ntypes,
            num_etypes=num_etypes,
            src=src,
            dst=dst,
            etype=etype,
            etype_ptr=etype_ptr,
            node_type=node_type,
            ntype_ptr=ntype_ptr,
            perm_dst=perm_dst.astype(np.int32),
            dst_sorted=dst_sorted,
            dst_ptr=dst_ptr,
            unique_src=unique_src,
            unique_etype=unique_etype,
            unique_etype_ptr=unique_etype_ptr,
            edge_to_unique=edge_to_unique.astype(np.int32),
        )

    # ------------------------------------------------------------------
    def to_device_graph(self) -> "DeviceGraph":
        """Upload the per-(dst, etype) CSC for device-native sampling.

        The destination-sorted edge view is already (dst-major,
        etype-minor) lexicographic — ``perm_dst`` is a stable sort of the
        etype-sorted canonical edges — so the fine-grained CSC needs only a
        bincount over ``dst * R + etype`` bins; ``csc_src`` *is*
        ``src[perm_dst]``, and a candidate's position in it is exactly the
        destination-sorted position the host sampler keys its counter-based
        randomness on. Built once (host) and uploaded once at engine build.
        """
        n, r = self.num_nodes, self.num_etypes
        if n * r >= 2**31:
            raise ValueError(
                f"device sampling needs num_nodes*num_etypes < 2^31 "
                f"(got {n}*{r}); shard the graph first")
        etype_d = self.etype[self.perm_dst]
        bins = self.dst_sorted.astype(np.int64) * r + etype_d
        counts = np.bincount(bins, minlength=n * r)
        indptr = np.zeros(n * r + 1, dtype=np.int32)
        np.cumsum(counts, out=indptr[1:])
        return DeviceGraph(
            csc_indptr=jnp.asarray(indptr),
            csc_src=jnp.asarray(self.src[self.perm_dst]),
            node_type=jnp.asarray(self.node_type),
            ntype_ptr=jnp.asarray(self.ntype_ptr),
            num_nodes=n,
            num_ntypes=self.num_ntypes,
            num_etypes=r,
            max_bin=int(counts.max()) if counts.size else 0,
        )

    # ------------------------------------------------------------------
    def to_tensors(self) -> "GraphTensors":
        return GraphTensors(
            src=jnp.asarray(self.src),
            dst=jnp.asarray(self.dst),
            etype=jnp.asarray(self.etype),
            etype_ptr=jnp.asarray(self.etype_ptr),
            node_type=jnp.asarray(self.node_type),
            ntype_ptr=jnp.asarray(self.ntype_ptr),
            perm_dst=jnp.asarray(self.perm_dst),
            dst_sorted=jnp.asarray(self.dst_sorted),
            dst_ptr=jnp.asarray(self.dst_ptr),
            unique_src=jnp.asarray(self.unique_src),
            unique_etype=jnp.asarray(self.unique_etype),
            unique_etype_ptr=jnp.asarray(self.unique_etype_ptr),
            edge_to_unique=jnp.asarray(self.edge_to_unique),
            num_nodes=self.num_nodes,
            num_ntypes=self.num_ntypes,
            num_etypes=self.num_etypes,
        )


@dataclasses.dataclass(frozen=True)
class GraphTensors:
    """Device pytree of graph index arrays (static metadata as aux fields)."""

    src: jnp.ndarray
    dst: jnp.ndarray
    etype: jnp.ndarray
    etype_ptr: jnp.ndarray
    node_type: jnp.ndarray
    ntype_ptr: jnp.ndarray
    perm_dst: jnp.ndarray
    dst_sorted: jnp.ndarray
    dst_ptr: jnp.ndarray
    unique_src: jnp.ndarray
    unique_etype: jnp.ndarray
    unique_etype_ptr: jnp.ndarray
    edge_to_unique: jnp.ndarray
    num_nodes: int = dataclasses.field(metadata={"static": True})
    num_ntypes: int = dataclasses.field(metadata={"static": True})
    num_etypes: int = dataclasses.field(metadata={"static": True})

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_unique(self) -> int:
        return int(self.unique_src.shape[0])


@dataclasses.dataclass(frozen=True)
class DeviceGraph:
    """Device-resident full-graph CSC for on-device fanout sampling.

    One (indptr, indices) pair at per-(destination, etype) granularity:
    ``csc_indptr[v*R + r] : csc_indptr[v*R + r + 1]`` spans node ``v``'s
    in-edges of type ``r`` inside ``csc_src`` (destination-sorted order, so
    positions double as the sampler's randomness counters). The
    presorted-by-ntype node invariant is preserved untouched — ``node_type``
    / ``ntype_ptr`` ride along for block node-type slicing. ``max_bin`` (the
    largest per-(dst, etype) in-degree) is the static candidate-window width
    of the device sampling kernel.
    """

    csc_indptr: jnp.ndarray   # [N*R + 1] int32
    csc_src: jnp.ndarray      # [E] int32 source node per dst-sorted edge
    node_type: jnp.ndarray    # [N] int32, non-decreasing
    ntype_ptr: jnp.ndarray    # [T+1] int32
    num_nodes: int = dataclasses.field(metadata={"static": True})
    num_ntypes: int = dataclasses.field(metadata={"static": True})
    num_etypes: int = dataclasses.field(metadata={"static": True})
    max_bin: int = dataclasses.field(metadata={"static": True})

    @property
    def num_edges(self) -> int:
        return int(self.csc_src.shape[0])


# register GraphTensors as a pytree: arrays are leaves, counts are static aux
import jax.tree_util as _tree_util  # noqa: E402

_ARRAY_FIELDS = [
    "src", "dst", "etype", "etype_ptr", "node_type", "ntype_ptr",
    "perm_dst", "dst_sorted", "dst_ptr",
    "unique_src", "unique_etype", "unique_etype_ptr", "edge_to_unique",
]
_STATIC_FIELDS = ["num_nodes", "num_ntypes", "num_etypes"]


def _gt_flatten(gt: GraphTensors):
    children = tuple(getattr(gt, f) for f in _ARRAY_FIELDS)
    aux = tuple(getattr(gt, f) for f in _STATIC_FIELDS)
    return children, aux


def _gt_unflatten(aux, children):
    kwargs = dict(zip(_ARRAY_FIELDS, children))
    kwargs.update(dict(zip(_STATIC_FIELDS, aux)))
    return GraphTensors(**kwargs)


_tree_util.register_pytree_node(GraphTensors, _gt_flatten, _gt_unflatten)


_DG_ARRAY_FIELDS = ["csc_indptr", "csc_src", "node_type", "ntype_ptr"]
_DG_STATIC_FIELDS = ["num_nodes", "num_ntypes", "num_etypes", "max_bin"]

_tree_util.register_pytree_node(
    DeviceGraph,
    lambda dg: (tuple(getattr(dg, f) for f in _DG_ARRAY_FIELDS),
                tuple(getattr(dg, f) for f in _DG_STATIC_FIELDS)),
    lambda aux, ch: DeviceGraph(**dict(zip(_DG_ARRAY_FIELDS, ch)),
                                **dict(zip(_DG_STATIC_FIELDS, aux))),
)


# ----------------------------------------------------------------------
# synthetic heterograph generator (Table 3 stand-ins; see DESIGN.md §8.2)
# ----------------------------------------------------------------------
def synthetic_heterograph(
    num_nodes: int,
    num_edges: int,
    num_ntypes: int,
    num_etypes: int,
    seed: int = 0,
    degree_alpha: float = 1.2,
    target_compaction: Optional[float] = None,
) -> HeteroGraph:
    """Power-law-ish heterograph matching (N, E, #ntypes, #etypes) statistics.

    ``target_compaction`` controls the entity-compaction ratio
    (#unique (src,etype) pairs / #edges, the paper's Fig. 10 metric): edges
    draw their (src, etype) from a pool of ~ratio*E unique pairs, replicating
    the source-reuse structure of the real datasets."""
    rng = np.random.default_rng(seed)
    # node types: dirichlet split, presorted
    props = rng.dirichlet(np.full(num_ntypes, 2.0))
    counts = np.maximum(1, (props * num_nodes).astype(np.int64))
    counts[-1] = max(1, num_nodes - int(counts[:-1].sum()))
    node_type = np.repeat(np.arange(num_ntypes, dtype=np.int32), counts)[:num_nodes]
    node_type = np.sort(node_type)
    # power-law destination popularity
    pop = rng.pareto(degree_alpha, size=num_nodes) + 1.0
    pop /= pop.sum()
    dst = rng.choice(num_nodes, size=num_edges, p=pop).astype(np.int32)
    if target_compaction is None:
        src = rng.integers(0, num_nodes, size=num_edges, dtype=np.int32)
        etype = rng.integers(0, num_etypes, size=num_edges, dtype=np.int32)
    else:
        u = max(1, int(num_edges * target_compaction))
        pool_src = rng.integers(0, num_nodes, size=u, dtype=np.int32)
        pool_et = rng.integers(0, num_etypes, size=u, dtype=np.int32)
        pick = np.concatenate([
            np.arange(u, dtype=np.int64),          # each pair used >= once
            rng.integers(0, u, size=max(0, num_edges - u)),
        ])[:num_edges]
        src, etype = pool_src[pick], pool_et[pick]
    return HeteroGraph.from_edges(
        src, dst, etype,
        num_nodes=num_nodes, num_etypes=num_etypes,
        node_type=node_type, num_ntypes=num_ntypes,
    )


# Published statistics of the paper's Table 3 datasets (post DGL/OGB
# preprocessing). Used by benchmarks with a scale factor for CPU tractability.
TABLE3_DATASETS = {
    # name: (num_nodes, num_ntypes, num_edges, num_etypes)
    "aifb":    (7_300,     7,  49_000,   104),
    "am":      (1_900_000, 7,  5_700_000, 108),
    "bgs":     (95_000,    27, 673_000,  122),
    "biokg":   (94_000,    5,  4_800_000, 51),
    "fb15k":   (15_000,    1,  620_000,  474),
    "mag":     (1_900_000, 4,  21_000_000, 4),
    "mutag":   (27_000,    5,  148_000,  50),
    "wikikg2": (2_500_000, 1,  16_000_000, 535),
}


# Entity-compaction ratios (Fig. 10): AM 57% and FB15k 26% are published in
# the paper text; the rest are estimates consistent with its Fig. 10 chart.
TABLE3_COMPACTION = {
    "aifb": 0.80, "am": 0.57, "bgs": 0.75, "biokg": 0.45,
    "fb15k": 0.26, "mag": 0.34, "mutag": 0.70, "wikikg2": 0.55,
}


# CPU-tractable scale factors (statistics proportional) shared by the
# benchmarks and the --reduced serving mode, so both run the same graphs.
CPU_REDUCED_SCALES = {
    "aifb": 0.5, "mutag": 0.2, "bgs": 0.03, "fb15k": 0.03,
    "biokg": 0.005, "am": 0.004, "mag": 0.001, "wikikg2": 0.001,
}


def table3_graph(name: str, scale: float = 1.0, seed: int = 0) -> HeteroGraph:
    n, nt, e, et = TABLE3_DATASETS[name]
    return synthetic_heterograph(
        max(8, int(n * scale)), max(8, int(e * scale)), nt, et, seed=seed,
        target_compaction=TABLE3_COMPACTION.get(name),
    )
