"""Pipeline parallelism over the "pod" axis (GPipe-style, shard_map).

At multi-pod scale the cross-pod link is the slowest; instead of pure DP
(gradient all-reduce of every parameter across pods), PP sends only
microbatch activations across the pod boundary. This module implements a
collective-permute pipeline:

  * layer stages are sharded over the ``pod`` axis (stage i on pod i),
  * microbatches stream through with ``jax.lax.ppermute`` handoffs,
  * the classic GPipe schedule: (M + P - 1) ticks for M microbatches and
    P stages; bubble fraction (P-1)/(M+P-1).

``pipeline_forward`` is numerically identical to running the stages
sequentially (tests/test_pipeline.py) and is differentiable (ppermute has a
transpose rule), so it composes with the training step.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_forward(
    stage_fn: Callable,       # (stage_params, x [mb, ...]) -> y [mb, ...]
    params,                   # pytree, leaves stacked [P, ...] over stages
    x: jnp.ndarray,           # [M, mb, ...] microbatches
    mesh,
    axis: str = "pod",
):
    """Run M microbatches through P = mesh.shape[axis] pipeline stages."""
    p = mesh.shape[axis]
    m = x.shape[0]

    param_specs = jax.tree.map(lambda _: P(axis), params)

    def body(stage_params, xl):
        # xl: [M, mb, ...] replicated copy of all microbatches
        stage_params = jax.tree.map(lambda a: a[0], stage_params)
        idx = jax.lax.axis_index(axis)
        ticks = m + p - 1
        mb_shape = xl.shape[1:]
        buf = jnp.zeros(mb_shape, xl.dtype)        # current activation
        outs = jnp.zeros((m,) + mb_shape, xl.dtype)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            feed = jnp.where(t < m, 1, 0)
            mb_in = jax.lax.dynamic_index_in_dim(
                xl, jnp.minimum(t, m - 1), axis=0, keepdims=False)
            buf = jnp.where(jnp.logical_and(idx == 0, feed)
                            , mb_in, buf)
            # every stage processes its current occupant
            active = jnp.logical_and(t - idx >= 0, t - idx < m)
            y = stage_fn(stage_params, buf)
            buf = jnp.where(active, y, buf)
            # last stage emits microbatch (t - p + 1)
            out_slot = jnp.clip(t - p + 1, 0, m - 1)
            emit = jnp.logical_and(idx == p - 1, t - (p - 1) >= 0)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(emit, buf, outs[out_slot]), out_slot, axis=0)
            # hand off to the next stage (ring; stage p-1 -> 0 is ignored)
            buf = jax.lax.ppermute(
                buf, axis, [(i, (i + 1) % p) for i in range(p)])
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # outs are only valid on the last stage; broadcast them
        outs = jax.lax.psum(
            jnp.where(idx == p - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(param_specs, P()),
        out_specs=P(),
        check_vma=False,
    )
    return fn(params, x)


def bubble_fraction(num_microbatches: int, num_stages: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)
