"""Fault tolerance runtime: heartbeats, failure detection, straggler
mitigation policy, elastic re-mesh orchestration.

On a real cluster the heartbeat source is the coordination service
(jax.distributed / GCS); here the monitor is driven by an injectable clock +
report stream so the policy logic is fully unit-testable on CPU. The train
driver (launch/train.py) wires it together with Checkpointer and
plan_elastic_mesh:

    failure detected -> drain -> plan_elastic_mesh(survivors)
    -> rebuild step on the new mesh -> Checkpointer.restore(shardings=new)
    -> resume from last step (data stream is a pure function of step, so
       no sample is lost or duplicated).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class HostState:
    last_heartbeat: float
    step: int = 0
    step_times: List[float] = dataclasses.field(default_factory=list)


class HeartbeatMonitor:
    """Declares hosts dead after ``timeout`` seconds of silence."""

    def __init__(self, hosts: List[str], timeout: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        now = clock()
        self.hosts: Dict[str, HostState] = {
            h: HostState(last_heartbeat=now) for h in hosts}

    def heartbeat(self, host: str, step: int = 0,
                  step_time: Optional[float] = None):
        st = self.hosts[host]
        st.last_heartbeat = self.clock()
        st.step = step
        if step_time is not None:
            st.step_times.append(step_time)
            if len(st.step_times) > 32:
                st.step_times.pop(0)

    def dead_hosts(self) -> List[str]:
        now = self.clock()
        return [h for h, st in self.hosts.items()
                if now - st.last_heartbeat > self.timeout]

    def alive_hosts(self) -> List[str]:
        dead = set(self.dead_hosts())
        return [h for h in self.hosts if h not in dead]

    # ------------------------------------------------------------------
    def stragglers(self, factor: float = 1.5) -> List[str]:
        """Hosts whose recent step time exceeds ``factor`` x fleet median."""
        meds = {}
        for h, st in self.hosts.items():
            if st.step_times:
                xs = sorted(st.step_times[-8:])
                meds[h] = xs[len(xs) // 2]
        if not meds:
            return []
        fleet = sorted(meds.values())[len(meds) // 2]
        return [h for h, m in meds.items() if m > factor * fleet]


@dataclasses.dataclass
class StragglerPolicy:
    """Mitigation decisions for slow hosts.

    * ``observe``: below trigger threshold — keep.
    * ``hot_swap``: persistent straggler and spares available — replace.
    * ``evict``: persistent straggler, no spares — elastic down-scale
      (cheaper than letting one host gate every synchronous step).
    """

    trigger_factor: float = 1.5
    persist_steps: int = 8
    _counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def decide(self, monitor: HeartbeatMonitor, spares: int = 0) -> Dict[str, str]:
        actions: Dict[str, str] = {}
        slow = set(monitor.stragglers(self.trigger_factor))
        for h in list(self._counts):
            if h not in slow:
                del self._counts[h]
        for h in slow:
            self._counts[h] = self._counts.get(h, 0) + 1
            if self._counts[h] < self.persist_steps:
                actions[h] = "observe"
            elif spares > 0:
                actions[h] = "hot_swap"
                spares -= 1
            else:
                actions[h] = "evict"
        return actions


@dataclasses.dataclass
class FailureEvent:
    step: int
    dead_hosts: List[str]
    surviving_devices: int


class ElasticController:
    """Drives the detect -> drain -> re-mesh -> restore -> resume sequence.

    The controller is transport-agnostic: ``rebuild`` is a callback that
    receives an ElasticPlan and returns the new (step_fn, state); the driver
    supplies it (launch/train.py).
    """

    def __init__(self, monitor: HeartbeatMonitor, devices_per_host: int,
                 model_parallel: int = 16):
        self.monitor = monitor
        self.devices_per_host = devices_per_host
        self.model_parallel = model_parallel
        self.events: List[FailureEvent] = []

    def check(self, step: int) -> Optional[FailureEvent]:
        dead = self.monitor.dead_hosts()
        if not dead:
            return None
        surviving = len(self.monitor.alive_hosts()) * self.devices_per_host
        ev = FailureEvent(step=step, dead_hosts=dead,
                          surviving_devices=surviving)
        self.events.append(ev)
        return ev

    def replan(self, ev: FailureEvent):
        from repro.launch.mesh import plan_elastic_mesh
        return plan_elastic_mesh(ev.surviving_devices,
                                 model_parallel=self.model_parallel)
