"""Hector authoring frontend: the Python-embedded DSL + the unified
``hector.compile()`` entry point.

    import hector                      # (or: from repro import frontend as hector)

    @hector.model
    def rgat(g, e, n, in_dim, out_dim, slope=0.01):
        ...

    compiled = hector.compile(rgat, graph, layers=2, sample=5)
    params = compiled.init(0)
    logits = compiled.apply(params, feats)            # full graph
    logits = compiled.apply_blocks(params, mb, feats) # sampled mini-batch
    state, metrics = compiled.train_step(state, mb, labels, feats)

Models trace to the existing ``ir.inter_op.Program`` (no new IR) and are
validated at trace time with source-located diagnostics
(``ProgramValidationError``).
"""
from repro.core.ir.validate import (  # noqa: F401
    ProgramValidationError,
    check_var_refs,
    validate_program,
)
from repro.frontend.compile import CompiledRGNN, compile  # noqa: F401,A004
from repro.frontend.trace import (  # noqa: F401
    ModelSpec,
    aggregate,
    concat,
    dot,
    edge_softmax,
    exp,
    leaky_relu,
    model,
    neg,
    relu,
    sigmoid,
    tanh,
    unary,
)

__all__ = [
    "model", "compile", "CompiledRGNN", "ModelSpec",
    "ProgramValidationError", "validate_program", "check_var_refs",
    "aggregate", "concat", "dot", "edge_softmax", "unary",
    "relu", "leaky_relu", "sigmoid", "tanh", "exp", "neg",
]
