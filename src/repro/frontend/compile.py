"""The unified ``hector.compile()`` front door.

One call takes a model (a DSL ``ModelSpec``, a registry name like
``"rgat"``, or any ``prog_fn(in_dim, out_dim, **kw) -> Program``) plus a
``HeteroGraph`` and builds the whole stack the three drivers used to wire
by hand: per-layer traced programs -> validated/lowered plans ->
``HectorStack`` with the compiled whole-plan executors -> fanout sampler ->
(optionally) the autotuner. The returned ``CompiledRGNN`` exposes the full
lifecycle — ``init`` / ``apply`` (full graph) / ``apply_blocks`` (sampled
mini-batch) / ``train_step`` (one compiled SGD step) — and delegates every
other attribute to the underlying ``RGNNEngine``, so serving and training
drivers run exclusively through this facade.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp

__all__ = ["compile", "CompiledRGNN"]


def _is_rng_key(x) -> bool:
    """True for int seeds, typed keys (jax.random.key) and legacy uint32
    [2] keys (jax.random.PRNGKey) — anything ``init`` can consume."""
    if isinstance(x, int):
        return True
    if not isinstance(x, jax.Array):
        return False
    if jax.dtypes.issubdtype(x.dtype, jax.dtypes.prng_key):
        return True
    return x.dtype == jnp.uint32 and x.shape == (2,)


class CompiledRGNN:
    """A compiled multi-layer RGNN bound to one graph.

    Thin facade over ``train.engine.RGNNEngine``: adds the unified
    ``init/apply/apply_blocks/train_step`` surface and forwards everything
    else (``make_loader``, ``tune_minibatch``, ``plans``, ``cfg``, ...) to
    the engine, so it drops into ``SampledTrainer``/``FullGraphTrainer``
    unchanged.
    """

    def __init__(self, engine, opt=None):
        self.engine = engine
        self._opt = opt

    def __getattr__(self, name):
        return getattr(self.engine, name)

    # -- model surface --------------------------------------------------
    def init(self, key: Union[jax.Array, int]):
        """Initialize per-layer parameter pytrees (int seeds accepted)."""
        if isinstance(key, int):
            key = jax.random.key(key)
        return self.engine.init_params(key)

    def apply(self, params, feats) -> jnp.ndarray:
        """Full-graph forward; ``feats`` is the [N, dim] input feature
        array (or a ``{"feature": array}`` dict)."""
        if isinstance(feats, dict):
            feats = feats["feature"]
        return self.engine.forward_full(params, feats)

    def apply_blocks(self, params, mb, global_feats,
                     compiled: bool = True) -> jnp.ndarray:
        """Sampled mini-batch forward over a ``sampling.MiniBatch``;
        returns one row per requested seed."""
        return self.engine.forward_minibatch(params, mb, global_feats,
                                             compiled=compiled)

    # -- training surface -----------------------------------------------
    def init_state(self, params_or_key, opt=None):
        """Optimizer state for ``train_step``. ``opt`` (an
        ``repro.optim.AdamW``, default lr=3e-3) is bound on first use."""
        if opt is not None:
            self._opt = opt
        params = params_or_key
        if _is_rng_key(params_or_key):
            params = self.init(params_or_key)
        return self._optimizer().init(params)

    def train_step(self, state, mb, labels, global_feats):
        """One compiled neighbor-sampled SGD step (block forward ->
        per-seed cross-entropy -> backward -> optimizer update) behind the
        signature compile cache. ``labels`` must align with the requested
        seed order (``mb.seq.slice_labels``); returns
        ``(new_state, {"loss", "accuracy"})``. ``global_feats`` may be the
        raw table or a ``repro.feats`` store (loader-attached ``mb.feats``
        take precedence either way)."""
        from repro.feats import gather_input
        exec_ = self._train_executor()
        return exec_.grad_and_update(state, mb, jnp.asarray(labels),
                                     gather_input(global_feats, mb))

    # -- observability ---------------------------------------------------
    def profile(self, params, mb, global_feats, *, warmup: int = 1,
                iters: int = 3):
        """Per-op kernel-time breakdown (the paper's Fig.-9 view) of one
        sampled mini-batch through this model's compiled block path.

        Steps the lowered plans op instance by op instance and times each
        in isolation on the tuner's measurement harness, next to the
        whole-plan compiled time. Returns an ``obs.profile.PlanProfile``
        (``.table()`` renders the breakdown, ``.to_json()`` exports it)."""
        from repro.obs import profile as _prof
        return _prof.profile_minibatch(self.engine, params, mb,
                                       global_feats, warmup=warmup,
                                       iters=iters)

    # -- internals -------------------------------------------------------
    def _optimizer(self):
        if self._opt is None:
            from repro.optim import AdamW
            self._opt = AdamW(learning_rate=3e-3)
        return self._opt

    def _train_executor(self):
        # one compiled step per (plans, opt): shared with SampledTrainer
        # through the engine-level cache
        return self.engine.train_executor(self._optimizer())

    def describe(self) -> str:
        """The generated plans, one per layer (paper Fig. 5 inspection)."""
        return "\n".join(p.describe() for p in self.engine.plans)

    def __repr__(self) -> str:
        cfg = self.engine.cfg
        return (f"CompiledRGNN<{cfg.model_name}: {cfg.layers} layers, "
                f"dims {cfg.dims}, backend {cfg.backend}>")


def compile(  # noqa: A001 - deliberate: the hector.compile() front door
    model,
    graph,
    *,
    layers: int = 2,
    dim: int = 64,
    hidden: int = 64,
    classes: int = 16,
    sample: Optional[Union[int, Sequence[int]]] = None,
    backend: str = "xla",
    tile: int = 32,
    node_block: int = 32,
    bucket: bool = True,
    activation: str = "relu",
    seed: int = 0,
    sampler: str = "host",
    dp: int = 1,
    partitions: Optional[int] = None,
    feature_store: str = "device",
    feature_budget: Optional[int] = None,
    tune: str = "off",
    tune_cache: Optional[str] = None,
    tune_full_graph: bool = True,
    opt=None,
    config=None,
    log=None,
    model_args: Optional[dict] = None,
    **model_kwargs,
) -> CompiledRGNN:
    """Compile ``model`` for ``graph`` and return a ``CompiledRGNN``.

    ``model``: a ``@hector.model`` ``ModelSpec``, a registry name
    (``"rgcn" | "rgat" | "hgt" | ...``), or any callable
    ``(in_dim, out_dim, **hparams) -> ir.inter_op.Program``. Model
    hyperparameters ride along as extra keyword arguments (or via
    ``model_args={...}`` when a name collides with a compile kwarg, e.g.
    a model-level ``activation``).

    ``sample``: per-hop neighbor fanout for the mini-batch paths — an int
    (same fanout every hop), a per-layer sequence, or ``-1`` for full
    neighborhoods. ``tune`` in {"off", "cached", "full"} runs the
    autotuner exactly as the drivers' ``--tune`` flag does.

    ``feature_store`` / ``feature_budget``: tiered feature storage
    (``repro.feats``) — "device" keeps the full node-feature table
    device-resident, "host" keeps it host-resident and ships only sampled
    rows, "cached" adds a fixed-budget device hot-row cache
    (``feature_budget`` rows, default table/4). Build the store with
    ``compiled.make_feature_store(feats)`` and hand it to ``make_loader``
    / ``train_step`` / ``apply_blocks`` wherever a raw table was accepted;
    predictions are bitwise identical across the three tiers.

    ``dp`` / ``partitions``: data-parallel execution (``repro.dist``) —
    the graph is edge-cut into ``partitions`` shards (default one per
    device) and the compiled train/serve steps run all shards under
    ``shard_map`` over a ``dp``-device data mesh, halo-feature exchange
    and gradient all-reduce included. The engine then exposes
    ``dist_batcher`` / ``dist_train_executor(opt)`` /
    ``dist_serve_executor()`` / ``shard_features(feats)``.

    ``config``: a prebuilt ``train.engine.EngineConfig`` (overrides every
    other compilation kwarg; ``model`` still wins if non-None).
    """
    import dataclasses

    from repro.train.engine import EngineConfig, RGNNEngine

    if config is not None:
        cfg = config if model is None else \
            dataclasses.replace(config, model=model)
    else:
        if isinstance(sample, int):
            sample = [sample] * layers
        prog_fn = model
        model_kwargs = {**(model_args or {}), **model_kwargs}
        if model_kwargs:
            import functools

            from repro.train.engine import MODEL_PROGRAMS
            if isinstance(model, str) and model not in MODEL_PROGRAMS:
                raise ValueError(f"unknown model {model!r}; "
                                 f"have {sorted(MODEL_PROGRAMS)}")
            base = MODEL_PROGRAMS[model] if isinstance(model, str) else model
            prog_fn = functools.partial(base, **model_kwargs)
            prog_fn.name = getattr(base, "name",
                                   getattr(base, "__name__", "custom"))
        cfg = EngineConfig(
            model=prog_fn, layers=layers, dim=dim, hidden=hidden,
            classes=classes, fanouts=sample, backend=backend, tile=tile,
            node_block=node_block, bucket=bucket, activation=activation,
            seed=seed, sampler=sampler, dp=dp, partitions=partitions,
            feature_store=feature_store, feature_budget=feature_budget,
            tune=tune, tune_cache=tune_cache,
            tune_full_graph=tune_full_graph)
    return CompiledRGNN(RGNNEngine(graph, cfg, log=log), opt=opt)
