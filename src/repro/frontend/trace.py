"""Tracing-based Python-embedded authoring DSL (paper §3.1 / Fig. 5).

A model is a plain Python function over three proxy objects::

    @hector.model
    def rgat(g, e, n, in_dim, out_dim, slope=0.01):
        W   = g.weight("W_rel", (in_dim, out_dim), indexed_by="etype")
        w_s = g.weight("w_att_src", (out_dim,), indexed_by="etype")
        w_t = g.weight("w_att_dst", (out_dim,), indexed_by="etype")
        e["hs"]      = e.src["feature"] @ W
        e["atts"]    = hector.dot(e["hs"], w_s)
        e["attt"]    = hector.dot(e.dst["feature"] @ W, w_t)
        e["att_raw"] = hector.leaky_relu(e["atts"] + e["attt"], slope)
        e["att"]     = hector.edge_softmax(e["att_raw"])
        n["h_out"]   = hector.aggregate(e["hs"], scale=e["att"])
        return n["h_out"]

Calling the decorated model (``rgat(64, 64)``) *traces* it: every
``e[...] = ...`` / ``n[...] = ...`` assignment appends one statement to an
``ir.inter_op.Program`` — the same for-each-edge / for-each-node IR the
hand-built model modules used to assemble from dataclasses — and the traced
program is validated at construction time (``ir.validate``) with
source-located diagnostics pointing at the offending model line. No new IR
is introduced: the tracer is purely a front end over ``inter_op``.

Semantics of the proxies:

* ``g.weight(name, shape, indexed_by=None)`` declares a model weight
  (per-type shape; ``indexed_by`` in {None, 'etype', 'ntype'}).
* ``e.src[name]`` / ``e.dst[name]`` read node data through the edge
  endpoints; ``e[name]`` reads a previously produced edge var; ``n[name]``
  reads a produced node var, or — if no statement wrote it — an input node
  feature.
* ``x @ W`` is the typed (or untyped) linear; ``+ - * /`` are elementwise
  with float->scalar promotion; ``hector.dot`` is the edgewise row dot.
* ``hector.edge_softmax`` / ``hector.aggregate`` build the composite
  statements (assign the former to ``e[...]``, the latter to ``n[...]``).
* ``return n[...]`` (or a tuple of reads) names the program outputs.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
import linecache
import os
import sys
from typing import Dict, List, Optional, Set, Tuple

from repro.core.ir import inter_op as I
from repro.core.ir.validate import ProgramValidationError, validate_program

__all__ = [
    "model", "ModelSpec", "dot", "concat", "edge_softmax", "aggregate",
    "unary", "relu", "leaky_relu", "sigmoid", "tanh", "exp", "neg",
]


def _user_loc(depth: int = 1) -> I.SourceLoc:
    """Source location of the model line currently executing: the caller
    ``depth`` frames above the DSL helper that asked."""
    fr = sys._getframe(depth + 1)
    fname, lineno = fr.f_code.co_filename, fr.f_lineno
    text = linecache.getline(fname, lineno).strip()
    return I.SourceLoc(os.path.basename(fname), lineno, text)


class _Trace:
    """Mutable per-trace state shared by the three proxies."""

    def __init__(self, name: str):
        self.name = name
        self.stmts: List[I.Stmt] = []
        self.source: Dict[int, I.SourceLoc] = {}
        self.edge_vars: Set[str] = set()
        self.node_vars: Set[str] = set()
        self.weights: Dict[str, I.Weight] = {}

    def fail(self, message: str, loc: Optional[I.SourceLoc]) -> None:
        raise ProgramValidationError(message, program=self.name, source=loc)

    def emit(self, stmt: I.Stmt, loc: I.SourceLoc) -> None:
        self.source[len(self.stmts)] = loc
        self.stmts.append(stmt)


# ---------------------------------------------------------------------------
# expression proxies
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Ex:
    """A traced expression; operator overloads build ``inter_op`` trees."""

    expr: I.Expr
    trace: _Trace = dataclasses.field(compare=False, repr=False)

    def _bin(self, op: str, other, swap: bool = False) -> "Ex":
        o = _as_expr(other, self.trace, _user_loc(2))
        a, b = (o, self.expr) if swap else (self.expr, o)
        return Ex(I.Binary(op, a, b), self.trace)

    def __add__(self, other):
        return self._bin("add", other)

    def __radd__(self, other):
        return self._bin("add", other, swap=True)

    def __sub__(self, other):
        return self._bin("sub", other)

    def __rsub__(self, other):
        return self._bin("sub", other, swap=True)

    def __mul__(self, other):
        return self._bin("mul", other)

    def __rmul__(self, other):
        return self._bin("mul", other, swap=True)

    def __truediv__(self, other):
        return self._bin("div", other)

    def __rtruediv__(self, other):
        return self._bin("div", other, swap=True)

    def __neg__(self):
        return Ex(I.Unary("neg", self.expr), self.trace)

    def __matmul__(self, w) -> "Ex":
        loc = _user_loc()
        if not isinstance(w, Wt):
            self.trace.fail(
                "the right operand of '@' must be a weight declared with "
                f"g.weight(...); got {type(w).__name__}", loc)
        if w.weight.indexed_by is None:
            return Ex(I.Linear(self.expr, w.weight), self.trace)
        return Ex(I.TypedLinear(self.expr, w.weight), self.trace)

    def dot(self, other) -> "Ex":
        return dot(self, other)


@dataclasses.dataclass(frozen=True)
class Wt:
    """A declared weight (wrapper so ``x @ W`` can pick Typed/untyped)."""

    weight: I.Weight
    trace: _Trace = dataclasses.field(compare=False, repr=False)


def _as_expr(v, trace: _Trace, loc: Optional[I.SourceLoc]) -> I.Expr:
    if isinstance(v, Ex):
        return v.expr
    if isinstance(v, Wt):
        return v.weight
    if isinstance(v, (int, float)):
        return I.Scalar(float(v))
    if isinstance(v, (_EdgeSoftmaxMarker, _AggregateMarker)):
        trace.fail(f"{v.what} is a statement, not an expression; assign it "
                   f"directly ({v.hint})", loc)
    trace.fail(f"cannot use {type(v).__name__} in a traced expression", loc)
    raise AssertionError  # unreachable


# ---------------------------------------------------------------------------
# composite-statement markers (consumed by e[...]= / n[...]= )
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class _EdgeSoftmaxMarker:
    src: Ex
    what: str = "edge_softmax(...)"
    hint: str = 'e["att"] = hector.edge_softmax(...)'


@dataclasses.dataclass(frozen=True)
class _AggregateMarker:
    msg: Ex
    scale: Optional[Ex]
    reduce: str
    what: str = "aggregate(...)"
    hint: str = 'n["h"] = hector.aggregate(...)'


def _edge_var_name(trace: _Trace, v, what: str, out: str,
                   loc: I.SourceLoc, tag: str = "in") -> str:
    """Resolve an argument that must name an edge var; non-var edge
    expressions are materialized into a derived statement first (``tag``
    keeps the temps of one consuming statement distinct)."""
    if isinstance(v, Ex) and isinstance(v.expr, I.NodeVar):
        trace.fail(f"{what} requires an edge var, but n[{v.expr.name}] is "
                   f"a node var (produced by a for-each-node statement)",
                   loc)
    if isinstance(v, Ex) and isinstance(v.expr, I.EdgeVar):
        return v.expr.name
    if isinstance(v, Ex):
        tmp = f"_{out}_{tag}"
        trace.emit(I.EdgeCompute(tmp, v.expr), loc)
        trace.edge_vars.add(tmp)
        return tmp
    trace.fail(f"{what} requires an edge expression; got "
               f"{type(v).__name__}", loc)
    raise AssertionError  # unreachable


# ---------------------------------------------------------------------------
# the three model-function proxies
# ---------------------------------------------------------------------------
class GraphProxy:
    """``g`` — the typed graph: weight declarations live here."""

    def __init__(self, trace: _Trace):
        self._trace = trace

    def weight(self, name: str, shape: Tuple[int, ...],
               indexed_by: Optional[str] = None) -> Wt:
        loc = _user_loc()
        tr = self._trace
        if indexed_by not in (None, "etype", "ntype", "ntype_src",
                              "ntype_dst"):
            tr.fail(f"weight '{name}': unknown indexed_by={indexed_by!r} "
                    f"(pick None, 'etype', 'ntype', 'ntype_src' or "
                    f"'ntype_dst')", loc)
        w = I.Weight(name, tuple(int(d) for d in shape), indexed_by)
        prev = tr.weights.get(name)
        if prev is not None and prev != w:
            tr.fail(f"weight '{name}' redeclared with a different "
                    f"shape/index: {prev} vs {w}", loc)
        tr.weights[name] = w
        return Wt(w, tr)


class _Endpoint:
    """``e.src`` / ``e.dst`` — node data read through an edge endpoint."""

    def __init__(self, trace: _Trace, cls):
        self._trace = trace
        self._cls = cls

    def __getitem__(self, name: str) -> Ex:
        return Ex(self._cls(str(name)), self._trace)


class EdgeProxy:
    """``e`` — the for-each-edge iteration variable."""

    def __init__(self, trace: _Trace):
        self._trace = trace
        self.src = _Endpoint(trace, I.SrcFeature)
        self.dst = _Endpoint(trace, I.DstFeature)

    def __getitem__(self, name: str) -> Ex:
        name = str(name)
        tr = self._trace
        if name not in tr.edge_vars:
            loc = _user_loc()
            if name in tr.node_vars:
                tr.fail(f"'{name}' is a node var; read it with n[{name!r}]"
                        f" (or via e.src/e.dst)", loc)
            have = sorted(tr.edge_vars) or ["<none>"]
            tr.fail(f"undefined edge var '{name}'; edge vars defined so "
                    f"far: {', '.join(have)}", loc)
        return Ex(I.EdgeVar(name), tr)

    def __setitem__(self, name: str, value) -> None:
        name, loc, tr = str(name), _user_loc(), self._trace
        if isinstance(value, _AggregateMarker):
            tr.fail("aggregate(...) reduces edges into nodes; assign it to "
                    f"n[{name!r}], not e[{name!r}]", loc)
        if isinstance(value, _EdgeSoftmaxMarker):
            src = _edge_var_name(tr, value.src, "edge_softmax", name, loc)
            tr.emit(I.EdgeSoftmax(name, src), loc)
        else:
            tr.emit(I.EdgeCompute(name, _as_expr(value, tr, loc)), loc)
        tr.edge_vars.add(name)


class NodeProxy:
    """``n`` — the for-each-node iteration variable. Reads of names no
    statement wrote resolve to *input* node features."""

    def __init__(self, trace: _Trace):
        self._trace = trace

    def __getitem__(self, name: str) -> Ex:
        name, tr = str(name), self._trace
        if name in tr.node_vars:
            return Ex(I.NodeVar(name), tr)
        if name in tr.edge_vars:
            tr.fail(f"'{name}' is an edge var; read it with e[{name!r}]",
                    _user_loc())
        return Ex(I.NodeFeature(name), tr)

    def __setitem__(self, name: str, value) -> None:
        name, loc, tr = str(name), _user_loc(), self._trace
        if isinstance(value, _EdgeSoftmaxMarker):
            tr.fail("edge_softmax(...) produces edge data; assign it to "
                    f"e[{name!r}], not n[{name!r}]", loc)
        if isinstance(value, _AggregateMarker):
            msg = _edge_var_name(tr, value.msg, "aggregate message", name,
                                 loc, tag="msg")
            scale = None
            if value.scale is not None:
                scale = _edge_var_name(tr, value.scale, "aggregate scale",
                                       name, loc, tag="scale")
            tr.emit(I.NodeAggregate(name, msg=msg, scale=scale,
                                    reduce=value.reduce), loc)
        else:
            tr.emit(I.NodeCompute(name, _as_expr(value, tr, loc)), loc)
        tr.node_vars.add(name)


# ---------------------------------------------------------------------------
# DSL operations
# ---------------------------------------------------------------------------
def dot(a, b) -> Ex:
    """Edgewise row dot product -> one scalar per edge (§3.3.1)."""
    loc = _user_loc()
    tr = a.trace if isinstance(a, Ex) else (
        b.trace if isinstance(b, (Ex, Wt)) else None)
    if tr is None:
        raise ProgramValidationError(
            "dot() needs traced operands", source=loc)
    return Ex(I.DotProduct(_as_expr(a, tr, loc), _as_expr(b, tr, loc)), tr)


def concat(*parts) -> Ex:
    loc = _user_loc()
    tr = next((p.trace for p in parts if isinstance(p, Ex)), None)
    if tr is None:
        raise ProgramValidationError(
            "concat() needs traced operands", source=loc)
    return Ex(I.Concat(tuple(_as_expr(p, tr, loc) for p in parts)), tr)


_UNARY_OPS = ("exp", "leaky_relu", "relu", "sigmoid", "neg", "tanh")


def _unary(op: str, x, alpha: float, loc: I.SourceLoc) -> Ex:
    if not isinstance(x, Ex):
        raise ProgramValidationError(
            f"{op}() needs a traced operand, got {type(x).__name__}",
            source=loc)
    if op not in _UNARY_OPS:
        x.trace.fail(f"unknown elementwise op {op!r}; pick one of "
                     f"{_UNARY_OPS}", loc)
    return Ex(I.Unary(op, x.expr, alpha), x.trace)


def unary(op: str, x, alpha: float = 0.01) -> Ex:
    """Generic elementwise unary (``op`` may be a model parameter)."""
    return _unary(op, x, alpha, _user_loc())


def relu(x) -> Ex:
    return _unary("relu", x, 0.01, _user_loc())


def leaky_relu(x, alpha: float = 0.01) -> Ex:
    return _unary("leaky_relu", x, alpha, _user_loc())


def sigmoid(x) -> Ex:
    return _unary("sigmoid", x, 0.01, _user_loc())


def tanh(x) -> Ex:
    return _unary("tanh", x, 0.01, _user_loc())


def exp(x) -> Ex:
    return _unary("exp", x, 0.01, _user_loc())


def neg(x) -> Ex:
    return _unary("neg", x, 0.01, _user_loc())


def edge_softmax(score) -> _EdgeSoftmaxMarker:
    """Softmax over the edges sharing a destination (paper Listing 1);
    assign the result to an edge var: ``e["att"] = edge_softmax(...)``."""
    loc = _user_loc()
    if isinstance(score, Ex) and isinstance(score.expr, I.NodeVar):
        score.trace.fail(
            f"edge_softmax requires an edge var, but n[{score.expr.name}] "
            f"is a node var (produced by a for-each-node statement)", loc)
    if not isinstance(score, Ex):
        raise ProgramValidationError(
            "edge_softmax() needs a traced edge expression", source=loc)
    return _EdgeSoftmaxMarker(score)


def aggregate(msg, scale=None, reduce: str = "sum") -> _AggregateMarker:
    """Per-destination reduction of edge messages (optionally scaled by an
    edge scalar, e.g. attention); assign to a node var:
    ``n["h"] = aggregate(e["msg"], scale=e["att"])``."""
    loc = _user_loc()
    if reduce not in ("sum", "mean"):
        raise ProgramValidationError(
            f"aggregate: unknown reduce {reduce!r}; pick 'sum' or 'mean'",
            source=loc)
    for v, what in ((msg, "aggregate message"), (scale, "aggregate scale")):
        if isinstance(v, Ex) and isinstance(v.expr, I.NodeVar):
            v.trace.fail(
                f"{what} requires an edge var, but n[{v.expr.name}] is a "
                f"node var (produced by a for-each-node statement)", loc)
    if not isinstance(msg, Ex):
        raise ProgramValidationError(
            "aggregate() needs a traced edge expression", source=loc)
    return _AggregateMarker(msg, scale, reduce)


# ---------------------------------------------------------------------------
# the @model decorator
# ---------------------------------------------------------------------------
class ModelSpec:
    """A DSL-authored model: calling it traces the function into a
    validated ``ir.inter_op.Program`` (so a ``ModelSpec`` is a drop-in
    ``prog_fn`` for ``EngineConfig``/``RGNNEngine``/``hector.compile``)."""

    def __init__(self, fn):
        self.fn = fn
        self.name = fn.__name__
        functools.update_wrapper(self, fn)

    def trace(self, *args, **kwargs) -> I.Program:
        tr = _Trace(self.name)
        g, e, n = GraphProxy(tr), EdgeProxy(tr), NodeProxy(tr)
        ret = self.fn(g, e, n, *args, **kwargs)
        outputs = self._outputs_of(ret, tr)
        prog = I.Program(stmts=tr.stmts, outputs=outputs, name=self.name,
                         source=dict(tr.source))
        return validate_program(prog)

    __call__ = trace

    @staticmethod
    def _outputs_of(ret, tr: _Trace) -> List[str]:
        items = ret if isinstance(ret, (tuple, list)) else (ret,)
        names: List[str] = []
        for it in items:
            if isinstance(it, Ex) and isinstance(it.expr,
                                                 (I.NodeVar, I.EdgeVar)):
                names.append(it.expr.name)
            else:
                tr.fail("a model must return produced vars (n[...] or "
                        f"e[...] reads); got {type(it).__name__}", None)
        if not names:
            tr.fail("a model must return at least one produced var", None)
        return names

    @property
    def definition_loc(self) -> int:
        """Non-blank, non-comment source lines of the model definition
        (decorator line excluded) — the paper's §4.1 programming-effort
        metric, reported by ``benchmarks/loc_report.py``."""
        src = inspect.getsource(self.fn)
        return sum(1 for line in src.splitlines()
                   if line.strip() and not line.strip().startswith(("#", "@")))

    def __repr__(self) -> str:
        return f"ModelSpec<{self.name}>"


def model(fn) -> ModelSpec:
    """Decorator: a plain function over ``(g, e, n, *dims, **hparams)``
    proxies becomes a traceable Hector model."""
    return ModelSpec(fn)
