"""``repro.feats`` — tiered node-feature storage (see ``store.py``).

The one factory every layer uses is ``make_feature_store``; consumers
duck-type against ``FeatureStore`` (``gather`` / ``host_rows`` /
``full_table`` / ``device_bytes``). ``as_feature_source`` normalizes the
"raw array or store" argument the engine/trainer surfaces accept.
"""
from repro.feats.store import (CachedFeatureStore,      # noqa: F401
                               DeviceFeatureStore, FeatureStore,
                               HostFeatureStore, make_feature_store,
                               split_budget)

__all__ = [
    "FeatureStore", "DeviceFeatureStore", "HostFeatureStore",
    "CachedFeatureStore", "make_feature_store", "split_budget",
    "is_feature_store", "gather_input",
]


def is_feature_store(obj) -> bool:
    """Duck-typed store check (anything exposing the gather protocol)."""
    return hasattr(obj, "gather") and hasattr(obj, "host_rows")


def gather_input(feats_or_store, mb):
    """The one rule for per-batch input features: a loader-attached
    pre-gathered pytree wins (the prefetch overlap already paid for it),
    else a store gathers the block's input rows, else the raw global
    array is indexed on device (the pre-tiering behavior)."""
    pre = getattr(mb, "feats", None)
    if pre is not None:
        return pre
    if is_feature_store(feats_or_store):
        return feats_or_store.gather(mb.input_ids, step=mb.step)
    import jax.numpy as jnp
    return {"feature": jnp.asarray(feats_or_store)[mb.input_ids]}
