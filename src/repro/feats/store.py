"""Tiered node-feature storage (ISSUE 9 tentpole).

Where the ``[N, dim]`` node-feature table lives decides the memory ceiling
of the whole stack: with the table device-resident (the only mode before
this package existed), feature scale — not graph-structure scale — is the
binding limit, undermining the paper's headline "never OOMs" claim for
OGB-size inputs. This package makes storage *tiered*:

* ``DeviceFeatureStore`` — the classic layout: the full table lives on
  device, per-batch input rows are a device-side gather. Fastest when it
  fits; the baseline the other tiers must match bitwise.

* ``HostFeatureStore`` — the table lives in **per-ntype host-resident
  arrays** (page-locked/pinned on real accelerator runtimes; on the CPU
  backend they are plain aligned NumPy arrays — the follow-up for real
  GPUs is UVA zero-copy gather, see ROADMAP). Only the sampled blocks'
  input rows are gathered per batch and shipped to device; the loader
  dispatches the gather for batch k+1 while batch k executes, so the
  transfer rides the existing prefetch overlap.

* ``CachedFeatureStore`` — fronts the host tier with a **fixed-budget
  device hot-row cache**: one slot slab ``[S, dim]`` on device,
  partitioned per ntype (``slot_ptr``, mirroring ``ntype_ptr``), with
  host-side index translation and CLOCK eviction decided on host from the
  sampled row ids. Hits never leave the device: the per-batch features
  are produced by one jitted insert+gather program whose cache state is
  threaded through as a **donated** input, so the slab is updated in
  place on accelerator backends and a fully-hot batch performs zero host
  feature work.

All three backends return bitwise-identical feature rows (the bits only
ever move; they are never recomputed), which is what lets every execution
mode — serve, train, device-sampled, distributed — switch tiers freely.

Observability: every gather runs under a ``feature_gather`` span;
``feature_cache_{hits,misses,evictions}`` counters,
``feature_bytes_moved`` (per-gather gauge) and
``feature_bytes_moved_total`` / ``feature_host_gathers`` counters land in
the metrics registry when enabled. The plain integer attributes on the
stores remain the always-on source of truth, same contract as the loader
LRUs.

Threading: a store is **single-writer** — exactly one ``MiniBatchLoader``
producer (or the driver thread) may call ``gather``; read-only surfaces
(``stats``, ``device_bytes``) are safe anywhere.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core.graph import HeteroGraph
from repro.kernels.layout import pow2ceil


_DONATION: Optional[bool] = None


def _donation_supported() -> bool:
    """Probe (once) whether the active backend honors buffer donation.

    Modern XLA:CPU aliases donated buffers just like GPU/TPU; older
    builds emit an "unused donation" warning and silently copy. Probing
    beats a backend allowlist: the slab update in ``CachedFeatureStore``
    is in-place wherever the runtime allows, and falls back to the
    functional copy (still bitwise-identical) where it does not."""
    global _DONATION
    if _DONATION is None:
        import warnings
        probe = jax.jit(lambda x: x.at[0].set(1.0), donate_argnums=(0,))
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            jax.block_until_ready(probe(jnp.zeros((2,), jnp.float32)))
        _DONATION = not any("donat" in str(w.message).lower()
                            for w in caught)
    return _DONATION


def split_budget(graph: HeteroGraph, budget: int,
                 weights: Optional[Sequence[float]] = None) -> np.ndarray:
    """Split ``budget`` cache rows across ntypes: proportional to
    ``weights`` (default: ntype populations), capped at each ntype's table
    size (slots beyond a table's row count can never hold a distinct row),
    with the remainder redistributed to uncapped types by weight.

    Returns per-ntype slot counts ``[T]`` summing to
    ``min(budget, num_nodes)``; a type can end up with zero slots (all its
    rows then ship uncached — correct, just never hot).
    """
    sizes = np.diff(graph.ntype_ptr).astype(np.int64)
    budget = int(min(max(0, budget), sizes.sum()))
    w = np.asarray(weights if weights is not None else sizes, np.float64)
    if w.shape != sizes.shape:
        raise ValueError(f"need {len(sizes)} weights, got {w.shape}")
    w = np.maximum(w, 0.0)
    slots = np.zeros(len(sizes), dtype=np.int64)
    remaining = budget
    free = w > 0
    # iterate: proportional assignment, cap at table size, redistribute
    while remaining > 0 and free.any() and w[free].sum() > 0:
        share = w * free / w[free].sum() * remaining
        add = np.minimum(np.floor(share).astype(np.int64), sizes - slots)
        if add.sum() == 0:  # round the largest fractional shares upward
            order = np.argsort(-share)
            for t in order:
                if remaining <= 0:
                    break
                if free[t] and slots[t] < sizes[t]:
                    slots[t] += 1
                    remaining -= 1
            break
        slots += add
        remaining -= int(add.sum())
        free = free & (slots < sizes)
    return slots.astype(np.int64)


class FeatureStore:
    """Protocol + shared host-side machinery for the three tiers.

    The surface every consumer codes against:

    * ``gather(ids, step=None) -> {"feature": jnp [n, dim]}`` — device-
      resident input rows for one batch (the executor feature pytree);
    * ``host_rows(ids) -> np [n, dim]`` — host-side row gather with no
      device involvement (the distributed slab builder reads through this,
      so shards never need the full table on device);
    * ``full_table() -> jnp [N, dim]`` — the whole table device-resident
      (full-graph eval/parity paths only; defeats tiering by design);
    * ``device_bytes()`` — persistent device bytes attributable to the
      store (the OOM-avoidance gate compares this against the full-table
      footprint).
    """

    kind = "base"

    def __init__(self, feats, graph: HeteroGraph):
        host = np.asarray(feats)
        if host.ndim != 2 or host.shape[0] != graph.num_nodes:
            raise ValueError(
                f"feature table must be [num_nodes={graph.num_nodes}, dim]; "
                f"got {host.shape}")
        self.graph = graph
        self.dim = int(host.shape[1])
        self.dtype = host.dtype
        self.itemsize = int(host.dtype.itemsize)
        self.num_rows = int(host.shape[0])
        self._host = np.ascontiguousarray(host)
        self.bytes_moved = 0
        self.rows_moved = 0
        self.host_gathers = 0   # batches that touched the host tables

    # -- protocol -------------------------------------------------------
    def gather(self, ids, step: Optional[int] = None) -> Dict[str, jnp.ndarray]:
        raise NotImplementedError

    def host_rows(self, ids) -> np.ndarray:
        """Host gather of global rows (no device work)."""
        return self._host[np.asarray(ids)]

    def full_table(self) -> jnp.ndarray:
        """The entire table on device — full-graph paths only."""
        return jnp.asarray(self._host)

    def device_bytes(self) -> int:
        return 0

    @property
    def table_bytes(self) -> int:
        """Footprint of the full table — the bound tiering must beat."""
        return self.num_rows * self.dim * self.itemsize

    def stats(self) -> dict:
        return {"kind": self.kind,
                "rows_moved": self.rows_moved,
                "bytes_moved": self.bytes_moved,
                "host_gathers": self.host_gathers,
                "device_bytes": self.device_bytes(),
                "table_bytes": self.table_bytes}

    # -- shared accounting ---------------------------------------------
    def _account_moved(self, rows: int) -> None:
        nbytes = rows * self.dim * self.itemsize
        self.rows_moved += rows
        self.bytes_moved += nbytes
        m = obs.metrics()
        m.gauge("feature_bytes_moved", store=self.kind).set(nbytes)
        m.counter("feature_bytes_moved_total", store=self.kind).inc(nbytes)


class DeviceFeatureStore(FeatureStore):
    """Today's behavior: full table device-resident, gather on device."""

    kind = "device"

    def __init__(self, feats, graph: HeteroGraph):
        super().__init__(feats, graph)
        self._table = jnp.asarray(self._host)
        # the one-time upload is the whole table
        self._account_moved(self.num_rows)

    def gather(self, ids, step=None) -> Dict[str, jnp.ndarray]:
        with obs.span("feature_gather", store=self.kind, step=step):
            return {"feature": self._table[jnp.asarray(ids)]}

    def full_table(self) -> jnp.ndarray:
        return self._table

    def device_bytes(self) -> int:
        return self.table_bytes


class HostFeatureStore(FeatureStore):
    """Host-resident tier: per-ntype host tables, block-row gather.

    ``tables[t]`` holds ntype ``t``'s rows (global rows
    ``ntype_ptr[t]:ntype_ptr[t+1]``) as an independent contiguous array —
    the layout a pinned-memory runtime registers per table. The gather
    translates global ids to (ntype, local row) through ``ntype_ptr``,
    reads host-side, and ships exactly the batch's rows; dispatch is
    asynchronous (``jax.device_put`` returns immediately), so calls made
    from the loader's producer overlap the consumer's compute.
    """

    kind = "host"

    def __init__(self, feats, graph: HeteroGraph):
        super().__init__(feats, graph)
        p = graph.ntype_ptr
        self.tables: List[np.ndarray] = [
            np.ascontiguousarray(self._host[int(p[t]):int(p[t + 1])])
            for t in range(graph.num_ntypes)]

    def host_rows(self, ids) -> np.ndarray:
        ids = np.asarray(ids, dtype=np.int64)
        ptr = self.graph.ntype_ptr.astype(np.int64)
        t = np.searchsorted(ptr, ids, side="right") - 1
        out = np.empty((ids.shape[0], self.dim), dtype=self.dtype)
        for tt in np.unique(t):
            m = t == tt
            out[m] = self.tables[int(tt)][ids[m] - ptr[int(tt)]]
        return out

    def gather(self, ids, step=None) -> Dict[str, jnp.ndarray]:
        ids = np.asarray(ids)
        with obs.span("feature_gather", store=self.kind, step=step):
            rows = self.host_rows(ids)
            self.host_gathers += 1
            self._account_moved(int(ids.shape[0]))
            obs.metrics().counter("feature_host_gathers",
                                  store=self.kind).inc()
            return {"feature": jax.device_put(rows)}


class CachedFeatureStore(HostFeatureStore):
    """Host tier fronted by a fixed-budget device hot-row cache.

    Device state is one slot slab ``slots [S, dim]`` partitioned per ntype
    by ``slot_ptr`` (ntype ``t`` owns slots ``slot_ptr[t]:slot_ptr[t+1]``).
    Host state is the index translation (``gid -> slot`` map, per-slot
    resident gid, CLOCK reference bits, per-ntype clock hands). Per batch:

    1. distinct requested rows are split into hits (already resident) and
       misses; CLOCK picks a victim slot for each miss *within its ntype's
       partition*, never evicting a slot this batch also reads (resident
       rows are pinned for the batch). Misses that find no victim
       (distinct batch rows exceed the partition) **overflow**: they ship
       to device for this batch but are not inserted.
    2. the miss rows are host-gathered and shipped (padded to a
       power-of-two bucket so the compiled program set stays fixed), and
       one jitted program scatters them into their slots (pad/overflow
       rows carry slot index ``S`` and drop) and gathers the batch's
       ``[n, dim]`` features from ``concat(slots, shipped)`` — cache hits
       therefore never leave the device. ``slots`` is donated on
       accelerator backends: the slab updates in place, and state is
       threaded functionally (``self.slots`` is rebound to the program's
       output every batch).
    3. a fully-hot batch (zero misses) runs a read-only gather program:
       no host rows touched, no transfer, no slab write.

    Eviction is decided entirely on host from the sampled row ids, so a
    fixed seed stream yields a bit-reproducible cache state trajectory.
    All host bookkeeping is vectorized — the id -> slot map is an int32
    array over the node population (4 B/node host memory, small next to
    the >= dim*4 B/node feature row itself) and victim selection is one
    batched CLOCK sweep per ntype — so the per-batch host cost is a few
    NumPy passes over the batch, not a Python loop over rows.
    """

    kind = "cached"

    def __init__(self, feats, graph: HeteroGraph, budget: int,
                 split: Optional[Sequence[int]] = None,
                 miss_bucket_min: int = 8):
        super().__init__(feats, graph)
        per_ntype = (np.asarray(split, np.int64) if split is not None
                     else split_budget(graph, budget))
        if per_ntype.shape != (graph.num_ntypes,):
            raise ValueError(
                f"split needs {graph.num_ntypes} entries, got {per_ntype}")
        sizes = np.diff(graph.ntype_ptr)
        if (per_ntype > sizes).any():
            raise ValueError("per-ntype slots exceed the ntype's table size")
        self.slot_ptr = np.zeros(graph.num_ntypes + 1, dtype=np.int64)
        np.cumsum(per_ntype, out=self.slot_ptr[1:])
        self.capacity = int(self.slot_ptr[-1])
        self.miss_bucket_min = int(miss_bucket_min)
        # device state: the slab (zeros until rows are inserted)
        self.slots = jnp.zeros((max(self.capacity, 1), self.dim),
                               dtype=self.dtype)
        # host state: index translation + CLOCK metadata
        self._slot_gid = np.full(max(self.capacity, 1), -1, dtype=np.int64)
        self._ref = np.zeros(max(self.capacity, 1), dtype=bool)
        self._hand = np.zeros(graph.num_ntypes, dtype=np.int64)
        self._gid2slot = np.full(self.num_rows, -1, dtype=np.int32)
        # counters (distinct requested rows per batch)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.overflows = 0
        self.trace_count = 0   # (re)traces of the two gather programs
        donate = (0,) if _donation_supported() else ()
        self._insert_fn = jax.jit(self._traced_insert_gather,
                                  donate_argnums=donate)
        self._hot_fn = jax.jit(self._traced_hot_gather)
        self._warmed: set = set()   # idx lengths whose programs are built

    # -- device programs ------------------------------------------------
    def _traced_insert_gather(self, slots, miss, ins, idx):
        # runs at trace time only (host): counts actual (re)traces so the
        # zero-retrace-after-warmup invariant is testable, like executors
        self.trace_count += 1
        obs.metrics().counter("feature_gather_traces").inc()
        slots = slots.at[ins].set(miss, mode="drop")
        # logical read source is concat(slots, miss)[idx]; materializing
        # that concat would copy the whole slab per batch, so read the two
        # halves separately (2 x [n, dim] gathers) and select
        S = slots.shape[0]
        in_slab = idx < S
        from_slab = slots[jnp.minimum(idx, S - 1)]
        from_ship = miss[jnp.clip(idx - S, 0, miss.shape[0] - 1)]
        return slots, jnp.where(in_slab[:, None], from_slab, from_ship)

    def _traced_hot_gather(self, slots, idx):
        self.trace_count += 1
        obs.metrics().counter("feature_gather_traces").inc()
        return slots[idx]

    def _prewarm(self, n_idx: int) -> None:
        """Compile the whole program set for batches of ``n_idx`` input
        rows up front: the hot gather plus every pow2 miss bucket up to
        ``n_idx``. Miss counts *shrink* as the cache warms, so without
        this, first-touch compiles of smaller buckets would show up as
        steady-state retraces. The warmup inserts nothing (every scatter
        index is the out-of-range drop slot), so cache state is untouched."""
        if n_idx in self._warmed:
            return
        self._warmed.add(n_idx)
        idx = jnp.zeros(n_idx, jnp.int32)
        self._hot_fn(self.slots, idx)
        S = int(self.slots.shape[0])
        mb = self.miss_bucket_min
        cap = max(pow2ceil(max(n_idx, 1)), self.miss_bucket_min)
        while mb <= cap:
            rows = jnp.zeros((mb, self.dim), self.dtype)
            ins = jnp.full((mb,), S, jnp.int32)      # all rows dropped
            self.slots, _ = self._insert_fn(self.slots, rows, ins, idx)
            mb *= 2

    # -- CLOCK eviction (host, one vectorized sweep per ntype) ---------
    def _pick_victims(self, t: int, k: int, pinned: np.ndarray) -> np.ndarray:
        """Up to ``k`` evictable slots in ntype ``t``'s partition, batch-
        CLOCK order: starting at the hand, unpinned-and-unreferenced slots
        first; if those run short the sweep dips into referenced slots
        (their second chance — the sweep clears their bits). Pinned slots
        (resident rows this batch reads) are never victims; fewer than
        ``k`` returned means the remainder overflows."""
        lo, hi = int(self.slot_ptr[t]), int(self.slot_ptr[t + 1])
        n = hi - lo
        if n == 0 or k <= 0:
            return np.empty(0, dtype=np.int64)
        order = lo + (int(self._hand[t]) + np.arange(n)) % n
        free = order[~pinned[order]]
        unref = free[~self._ref[free]]
        if unref.shape[0] >= k:
            victims = unref[:k]
        else:
            refd = free[self._ref[free]]
            self._ref[refd] = False      # swept past: second chance spent
            victims = np.concatenate([unref, refd])[:k]
        self._hand[t] = (int(self._hand[t]) + victims.shape[0]) % n
        return victims

    # -- the batch gather ----------------------------------------------
    def gather(self, ids, step=None) -> Dict[str, jnp.ndarray]:
        ids = np.asarray(ids)
        with obs.span("feature_gather", store=self.kind, step=step):
            return {"feature": self._gather_impl(ids)}

    def _gather_impl(self, ids: np.ndarray) -> jnp.ndarray:
        ptr = self.graph.ntype_ptr.astype(np.int64)
        uniq, inv = np.unique(ids.astype(np.int64), return_inverse=True)
        m = obs.metrics()
        self._prewarm(int(ids.shape[0]))

        slot_of = self._gid2slot[uniq].astype(np.int64)
        resident = slot_of >= 0
        hit_slots = slot_of[resident]
        miss_gids = uniq[~resident]
        self._ref[hit_slots] = True
        n_hit = int(resident.sum())
        n_miss = int(miss_gids.shape[0])
        self.hits += n_hit
        m.counter("feature_cache_hits").inc(n_hit)
        self.misses += n_miss
        m.counter("feature_cache_misses").inc(n_miss)

        S = int(self.slots.shape[0])
        if n_miss == 0:
            # fully hot: read-only slab gather, zero host feature work.
            # The int32 cast happens in NumPy and the array is handed to
            # the jitted call as-is: jit's argument-transfer path is far
            # cheaper than an eager device_put + dtype convert per batch.
            return self._hot_fn(self.slots, slot_of[inv].astype(np.int32))

        # victim assignment: one batched CLOCK sweep per ntype, in
        # ascending (ntype, gid) order — fully deterministic. Resident
        # rows this batch reads are pinned.
        pinned = np.zeros(S, dtype=bool)
        pinned[hit_slots] = True
        t_of = np.searchsorted(ptr, miss_gids, side="right") - 1
        ins_gids: List[np.ndarray] = []
        ins_slots: List[np.ndarray] = []
        over_gids: List[np.ndarray] = []
        n_evict = 0
        for t in np.unique(t_of):
            gids_t = miss_gids[t_of == t]     # sorted (uniq is sorted)
            victims = self._pick_victims(int(t), gids_t.shape[0], pinned)
            k = victims.shape[0]
            take = gids_t[:k]
            old = self._slot_gid[victims]
            live = old >= 0
            self._gid2slot[old[live]] = -1
            n_evict += int(live.sum())
            self._slot_gid[victims] = take
            self._gid2slot[take] = victims
            self._ref[victims] = True
            pinned[victims] = True            # this batch now reads them
            ins_gids.append(take)
            ins_slots.append(victims)
            if k < gids_t.shape[0]:           # overflow: ship uninserted
                over_gids.append(gids_t[k:])
        self.evictions += n_evict
        m.counter("feature_cache_evictions").inc(n_evict)

        inserted = np.concatenate(ins_gids) if ins_gids else \
            np.empty(0, dtype=np.int64)
        inserted_slots = np.concatenate(ins_slots) if ins_slots else \
            np.empty(0, dtype=np.int64)
        overflow = np.concatenate(over_gids) if over_gids else \
            np.empty(0, dtype=np.int64)
        n_over = int(overflow.shape[0])
        self.overflows += n_over
        if n_over:
            m.counter("feature_cache_overflows").inc(n_over)

        # per-distinct-row read source: cache slot for hits and freshly
        # inserted misses (the hit path and warm path share one compiled
        # access pattern), S + k for the k-th shipped overflow row
        uniq_read = self._gid2slot[uniq].astype(np.int64)
        if n_over:
            # shipped order: inserted misses first, overflow rows after
            pos = np.searchsorted(uniq, overflow)
            uniq_read[pos] = S + inserted.shape[0] + np.arange(n_over)
        shipped = np.concatenate([inserted, overflow])

        mb = max(pow2ceil(shipped.shape[0]), self.miss_bucket_min)
        rows = np.zeros((mb, self.dim), dtype=self.dtype)
        rows[: shipped.shape[0]] = self.host_rows(shipped)
        ins = np.full(mb, S, dtype=np.int64)   # S = out-of-range => dropped
        ins[: inserted.shape[0]] = inserted_slots

        self.host_gathers += 1
        m.counter("feature_host_gathers", store=self.kind).inc()
        self._account_moved(int(shipped.shape[0]))
        # NumPy operands go to the jitted call untouched — its transfer
        # path is one batched copy, vs ~3 dispatched device_puts eagerly
        self.slots, out = self._insert_fn(
            self.slots, rows, ins.astype(np.int32),
            uniq_read[inv].astype(np.int32))
        return out

    # -- reporting ------------------------------------------------------
    def device_bytes(self) -> int:
        return int(self.slots.shape[0]) * self.dim * self.itemsize

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        out = super().stats()
        out.update(hits=self.hits, misses=self.misses,
                   evictions=self.evictions, overflows=self.overflows,
                   hit_rate=self.hit_rate, capacity=self.capacity,
                   trace_count=self.trace_count,
                   slot_ptr=self.slot_ptr.tolist())
        g = obs.metrics().gauge("feature_cache_hit_rate")
        g.set(self.hit_rate)
        obs.metrics().gauge("feature_device_bytes").set(self.device_bytes())
        return out


def make_feature_store(feats, graph: HeteroGraph, kind: str = "device",
                       budget: Optional[int] = None,
                       split: Optional[Sequence[int]] = None) -> FeatureStore:
    """Build a feature store. ``kind`` in {"device", "host", "cached"};
    ``budget`` (cached only) is the device hot-row count, default one
    quarter of the table; ``split`` overrides the per-ntype slot split
    (e.g. the measured decision from ``tune.feature_budget``)."""
    if kind == "device":
        return DeviceFeatureStore(feats, graph)
    if kind == "host":
        return HostFeatureStore(feats, graph)
    if kind == "cached":
        if budget is None:
            budget = max(1, graph.num_nodes // 4)
        return CachedFeatureStore(feats, graph, budget=budget, split=split)
    raise ValueError(f"feature_store={kind!r}; pick device/host/cached")
