"""``import hector`` — the public front door to the Hector reproduction.

Re-exports the authoring DSL (``@hector.model`` + the edge/node operations)
and the unified ``hector.compile()`` facade from ``repro.frontend``::

    import hector

    @hector.model
    def rgcn(g, e, n, in_dim, out_dim, activation="relu"):
        W_r = g.weight("W_rel", (in_dim, out_dim), indexed_by="etype")
        W_0 = g.weight("W_self", (in_dim, out_dim))
        e["msg"] = e.src["feature"] @ W_r
        n["h_agg"] = hector.aggregate(e["msg"], reduce="mean")
        n["h_self"] = n["feature"] @ W_0
        n["h_out"] = hector.unary(activation, n["h_agg"] + n["h_self"])
        return n["h_out"]

    compiled = hector.compile(rgcn, graph, layers=2, sample=5)
"""
from repro.frontend import *  # noqa: F401,F403
from repro.frontend import __all__  # noqa: F401
